//! High-level LC engine: one-query-vs-database distance computation for
//! every [`Method`], plus the all-pairs symmetric evaluation used by the
//! accuracy experiments (paper Section 6).
//!
//! Linear-complexity methods (BoW, WCD, LC-RWMD, LC-OMR, LC-ACT) run the
//! batched Phase-1/Phase-2 pipeline.  The quadratic comparators
//! (BoW-adjusted, ICT, Sinkhorn, exact EMD) fall back to a data-parallel
//! per-pair sweep dispatched through [`MethodRegistry`] trait objects, so
//! every method is reachable behind the same engine interface.
//!
//! For all-pairs runs, the symmetric measure `max(m(a→b), m(b→a))` is
//! assembled from two asymmetric direction-A sweeps (document b scores
//! query a's sweep and vice versa), exactly how the paper evaluates — no
//! per-pair quadratic work for the LC family.

use crate::approx::{bow_distances_batch, centroids_batch, wcd_from_centroids};
use std::sync::Arc;

use crate::core::{
    BatchDistance, CompressedKind, CsrMatrix, Dataset, Distance, EmdResult, F16Tier, Histogram,
    Method, MethodRegistry, Metric,
};
use crate::util::threadpool::{parallel_for, parallel_map, SyncSlice};

use super::batch_plan::{BatchPlanner, PlanScratch, DEFAULT_BATCH_BLOCK};
use super::kernels::KernelBackend;
use super::plan::{plan_query, PlanParams, QueryPlan};
use super::transfers::{
    act_direction_a_into, direction_a_block_into, direction_b_block_into, omr_direction_a_into,
    rwmd_direction_a_into, rwmd_direction_b_into,
};

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineParams {
    pub metric: Metric,
    pub threads: usize,
    /// Also compute direction-B RWMD and take the max (single-query mode).
    pub symmetric: bool,
    /// Phase-1 block size `B`: how many queries the batched multi-query
    /// kernel plans per vocabulary pass (all-pairs sweeps and
    /// [`LcEngine::distances_batch`]).
    ///
    /// Memory note: in symmetric mode each in-flight plan keeps a full
    /// `(v, h)` direction-B matrix, so `distances_batch` holds up to
    /// `B · v · h` f32 at once — size `B` accordingly for large
    /// vocabularies (all-pairs sweeps run with `keep_d: false` and are
    /// unaffected).
    pub batch_block: usize,
    /// Forced Phase-1 kernel backend; `None` picks the best the host
    /// supports (overridable process-wide via `EMDPAR_KERNEL`).  Purely a
    /// speed knob — all backends are bit-identical.
    pub kernel: Option<KernelBackend>,
    /// Compressed stage-1 residency: [`CompressedKind::F16`] keeps an f16
    /// copy of the embedding table that candidate-scoring sweeps may stream
    /// instead of the f32 original (callers opt in per call through the
    /// `*_tiered` entry points; the query planner recovers exactness with
    /// an f32 rerank).
    pub compressed: CompressedKind,
}

impl Default for EngineParams {
    fn default() -> Self {
        EngineParams {
            metric: Metric::L2,
            threads: crate::util::threadpool::default_threads(),
            symmetric: true,
            batch_block: DEFAULT_BATCH_BLOCK,
            kernel: None,
            compressed: CompressedKind::Off,
        }
    }
}

/// The engine's f16 stage-1 tier: the encoded table plus its own
/// squared-norm table (decoded-value norms, so compressed Gram expansions
/// are internally consistent).
struct CompressedVocab {
    tier: F16Tier,
    vn: Vec<f32>,
}

/// The native (CPU data-parallel) LC engine over one database.
///
/// Owns a shared handle to the dataset plus the per-database precomputations
/// (BoW row norms, WCD centroids, vocabulary squared norms for the Phase-1
/// Gram expansion) so constructing it once and reusing it per query is cheap
/// — the coordinator caches one engine per dataset.
pub struct LcEngine {
    dataset: Arc<Dataset>,
    params: EngineParams,
    bow_norms: Vec<f32>,
    centroids: Vec<f64>,
    /// `|v_i|²` per vocabulary row, shared by every Phase-1 plan (computing
    /// this per `plan_query` call was an `O(n·v·m)` term in all-pairs mode).
    vocab_sq_norms: Vec<f32>,
    /// Built once in `new` (the seed rebuilt a registry on every
    /// per-pair call).
    registry: MethodRegistry,
    /// `Some` when [`EngineParams::compressed`] requested a stage-1 tier.
    compressed: Option<CompressedVocab>,
}

impl LcEngine {
    pub fn new(dataset: Arc<Dataset>, params: EngineParams) -> LcEngine {
        let threads = params.threads;
        Self::with_precompute_threads(dataset, params, threads)
    }

    /// [`LcEngine::new`] with a separate thread budget for the one-time
    /// precomputations (WCD centroids etc.).  The sharded corpus builds its
    /// shard engines **serially** — full pool available — but searches them
    /// **concurrently** on per-shard budgets, so construction and serving
    /// want different widths.  Precompute results are bit-identical across
    /// thread counts, so this is purely a scheduling knob.
    pub fn with_precompute_threads(
        dataset: Arc<Dataset>,
        params: EngineParams,
        precompute_threads: usize,
    ) -> LcEngine {
        let compressed = match params.compressed {
            CompressedKind::Off => None,
            CompressedKind::F16 => {
                let tier = dataset.embeddings.compressed_tier();
                let vn = tier.row_sq_norms();
                Some(CompressedVocab { tier, vn })
            }
        };
        LcEngine {
            bow_norms: dataset.matrix.row_l2_norms(),
            centroids: centroids_batch(
                &dataset.embeddings,
                &dataset.matrix,
                precompute_threads.max(1),
            ),
            vocab_sq_norms: dataset.embeddings.row_sq_norms(),
            registry: MethodRegistry::new(params.metric),
            compressed,
            dataset,
            params,
        }
    }

    /// Whether this engine carries an f16 compressed stage-1 tier (the
    /// query planner only routes compressed stages to engines where this
    /// holds).
    pub fn compressed_active(&self) -> bool {
        self.compressed.is_some()
    }

    /// The Phase-1 planner for this engine: compressed-tier when requested
    /// *and* built, the exact f32 table otherwise.
    fn batch_planner(&self, compressed: bool) -> BatchPlanner<'_> {
        match (&self.compressed, compressed) {
            (Some(cv), true) => BatchPlanner::new_compressed(&cv.tier, &cv.vn),
            _ => BatchPlanner::new(&self.dataset.embeddings, &self.vocab_sq_norms),
        }
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    pub fn params(&self) -> &EngineParams {
        &self.params
    }

    /// The precomputed vocabulary row squared-norm table (Phase-1 input).
    pub fn vocab_sq_norms(&self) -> &[f32] {
        &self.vocab_sq_norms
    }

    /// The precomputed per-document WCD centroid matrix, row-major `(n, m)`
    /// — the WCD fast path's table and the training input of the IVF
    /// pruning index ([`crate::index::IvfIndex::train`]).
    pub fn wcd_centroids(&self) -> &[f64] {
        &self.centroids
    }

    /// The registry configured with this engine's ground metric — the object
    /// the per-pair fallback and the cascade's rerank stage dispatch through.
    pub fn registry(&self) -> MethodRegistry {
        self.registry
    }

    /// Distances from one query histogram to every database row (direction
    /// A; plus max with direction-B RWMD when `symmetric` is set).  Per-pair
    /// methods always compute their symmetric form.
    pub fn distances(&self, query: &Histogram, method: Method) -> Vec<f32> {
        let db = &self.dataset.matrix;
        match method {
            Method::Bow => bow_distances_batch(query, db, &self.bow_norms)
                .into_iter()
                .map(|d| d as f32)
                .collect(),
            Method::Wcd => {
                let qc = crate::approx::centroid(&self.dataset.embeddings, query);
                let m = self.dataset.embeddings.dim();
                // data-parallel over database rows, like every other method
                parallel_map(db.nrows(), self.params.threads, |u| {
                    wcd_from_centroids(&qc, &self.centroids[u * m..(u + 1) * m]) as f32
                })
            }
            Method::Rwmd | Method::Omr | Method::Act { .. } => {
                let keep_d = self.params.symmetric;
                let plan = plan_query(
                    &self.dataset.embeddings,
                    &self.vocab_sq_norms,
                    query,
                    PlanParams {
                        k: method.plan_k(),
                        metric: self.params.metric,
                        keep_d,
                        threads: self.params.threads,
                        kernel: self.params.kernel,
                    },
                );
                let mut t = vec![0.0f32; db.nrows()];
                let mut tb = Vec::new();
                self.phase2_into(method, &plan, db, &mut t, self.params.threads, &mut tb);
                t
            }
            _ => self.per_pair_row(query, method),
        }
    }

    /// Phase 2 (+ direction-B max when the engine is symmetric) for one
    /// plan, written into a caller-owned row.  `db` is the CSR matrix to
    /// sweep — the full database, or a gathered candidate subset
    /// ([`LcEngine::distances_batch_subset`]): each row's transfer cost is
    /// independent of the other rows, so subset values are bit-identical to
    /// the full sweep's.  `tb` is a reusable scratch row for the
    /// direction-B sweep, so batched callers pay zero per-query allocations
    /// here too.
    fn phase2_into(
        &self,
        method: Method,
        plan: &QueryPlan,
        db: &CsrMatrix,
        out: &mut [f32],
        threads: usize,
        tb: &mut Vec<f32>,
    ) {
        match method {
            Method::Rwmd => rwmd_direction_a_into(plan, db, threads, out),
            Method::Omr => omr_direction_a_into(plan, db, threads, out),
            _ => act_direction_a_into(plan, db, threads, out),
        }
        if plan.d.is_some() {
            tb.resize(db.nrows(), 0.0);
            rwmd_direction_b_into(plan, db, threads, tb);
            for (a, &b) in out.iter_mut().zip(tb.iter()) {
                if b > *a {
                    *a = b;
                }
            }
        }
    }

    /// Row-major `(queries.len(), n)` distances for a block of queries —
    /// the multi-query fast path.  The LC plan methods (RWMD/OMR/ACT) are
    /// planned in blocks of [`EngineParams::batch_block`] through the tiled
    /// multi-query Phase-1 kernel ([`BatchPlanner`]), reusing one
    /// [`PlanScratch`] arena across the whole call; rows are bit-identical
    /// to per-query [`LcEngine::distances`].  Plan-free and per-pair
    /// methods evaluate row by row.
    pub fn distances_batch(&self, queries: &[Histogram], method: Method) -> Vec<f32> {
        self.distances_batch_tiered(queries, method, false)
    }

    /// [`LcEngine::distances_batch`] with an explicit residency choice:
    /// `compressed: true` streams the f16 stage-1 tier through Phase 1
    /// (when the engine carries one — exact f32 otherwise).  Compressed
    /// rows are *approximate* candidate scores; callers needing exact
    /// values rerank through the exact path (the query planner does this
    /// automatically).
    pub fn distances_batch_tiered(
        &self,
        queries: &[Histogram],
        method: Method,
        compressed: bool,
    ) -> Vec<f32> {
        let n = self.dataset.len();
        if queries.is_empty() {
            return Vec::new();
        }
        if !matches!(method, Method::Rwmd | Method::Omr | Method::Act { .. }) {
            let mut out = Vec::with_capacity(queries.len() * n);
            for q in queries {
                out.extend_from_slice(&self.distances(q, method));
            }
            return out;
        }
        let keep_d = self.params.symmetric;
        let bb = self.params.batch_block.max(1);
        let threads = self.params.threads;
        let params = PlanParams {
            k: method.plan_k(),
            metric: self.params.metric,
            keep_d,
            threads,
            kernel: self.params.kernel,
        };
        let planner = self.batch_planner(compressed);
        let mut scratch = PlanScratch::new();
        let mut plans: Vec<QueryPlan> = Vec::new();
        let mut out = vec![0.0f32; queries.len() * n];
        let mut tb = Vec::new();
        for (b, block) in queries.chunks(bb).enumerate() {
            planner.plan_block_into(block, params, &mut scratch, &mut plans);
            let q0 = b * bb;
            self.phase2_block_into(
                method,
                &plans,
                &self.dataset.matrix,
                &mut out[q0 * n..(q0 + plans.len()) * n],
                threads,
                &mut tb,
            );
        }
        out
    }

    /// Phase 2 for a whole Phase-1 block of plans in one database pass
    /// (each CSR row fetched once for all plans — see
    /// [`direction_a_block_into`]), plus the direction-B max when the
    /// plans carry D.  Bit-identical to per-plan [`LcEngine::phase2_into`]
    /// calls because both shapes share the same per-row cost helpers.
    fn phase2_block_into(
        &self,
        method: Method,
        plans: &[QueryPlan],
        db: &CsrMatrix,
        out: &mut [f32],
        threads: usize,
        tb: &mut Vec<f32>,
    ) {
        direction_a_block_into(method, plans, db, threads, out);
        if plans.iter().all(|p| p.d.is_some()) && !plans.is_empty() {
            tb.resize(out.len(), 0.0);
            direction_b_block_into(plans, db, threads, &mut tb[..out.len()]);
            for (a, &b) in out.iter_mut().zip(tb.iter()) {
                if b > *a {
                    *a = b;
                }
            }
        }
    }

    /// Row-major `(queries.len(), ids.len())` distances restricted to the
    /// database rows `ids` (ascending, unique) — the scoring half of
    /// IVF-pruned search ([`crate::index::search`]).  The candidate rows
    /// are gathered into a sub-CSR matrix once per call and the queries
    /// flow through the same batched Phase-1 block pipeline as
    /// [`LcEngine::distances_batch`]; because every Phase-2 row cost is
    /// independent of its neighbors, each value is bit-identical to the
    /// corresponding entry of the full sweep.
    pub fn distances_batch_subset(
        &self,
        queries: &[Histogram],
        method: Method,
        ids: &[u32],
    ) -> Vec<f32> {
        self.distances_batch_subset_tiered(queries, method, ids, false)
    }

    /// [`LcEngine::distances_batch_subset`] with an explicit residency
    /// choice (see [`LcEngine::distances_batch_tiered`]).
    pub fn distances_batch_subset_tiered(
        &self,
        queries: &[Histogram],
        method: Method,
        ids: &[u32],
        compressed: bool,
    ) -> Vec<f32> {
        if queries.is_empty() || ids.is_empty() {
            return Vec::new();
        }
        debug_assert!(ids.windows(2).all(|w| w[0] < w[1]), "candidate ids must be ascending");
        debug_assert!(
            ids.iter().all(|&u| (u as usize) < self.dataset.len()),
            "candidate id out of range"
        );
        let cols = ids.len();
        match method {
            Method::Bow => {
                let sub = self.gather_rows(ids);
                let norms: Vec<f32> = ids.iter().map(|&u| self.bow_norms[u as usize]).collect();
                let mut out = Vec::with_capacity(queries.len() * cols);
                for q in queries {
                    out.extend(
                        bow_distances_batch(q, &sub, &norms).into_iter().map(|d| d as f32),
                    );
                }
                out
            }
            Method::Wcd => {
                let m = self.dataset.embeddings.dim();
                let mut out = Vec::with_capacity(queries.len() * cols);
                for q in queries {
                    let qc = crate::approx::centroid(&self.dataset.embeddings, q);
                    out.extend(ids.iter().map(|&u| {
                        let u = u as usize;
                        wcd_from_centroids(&qc, &self.centroids[u * m..(u + 1) * m]) as f32
                    }));
                }
                out
            }
            Method::Rwmd | Method::Omr | Method::Act { .. } => {
                let sub = self.gather_rows(ids);
                let keep_d = self.params.symmetric;
                let bb = self.params.batch_block.max(1);
                let threads = self.params.threads;
                let params = PlanParams {
                    k: method.plan_k(),
                    metric: self.params.metric,
                    keep_d,
                    threads,
                    kernel: self.params.kernel,
                };
                let planner = self.batch_planner(compressed);
                let mut scratch = PlanScratch::new();
                let mut plans: Vec<QueryPlan> = Vec::new();
                let mut out = vec![0.0f32; queries.len() * cols];
                let mut tb = Vec::new();
                for (b, block) in queries.chunks(bb).enumerate() {
                    planner.plan_block_into(block, params, &mut scratch, &mut plans);
                    let q0 = b * bb;
                    self.phase2_block_into(
                        method,
                        &plans,
                        &sub,
                        &mut out[q0 * cols..(q0 + plans.len()) * cols],
                        threads,
                        &mut tb,
                    );
                }
                out
            }
            _ => {
                // per-pair fallback through the registry's boxed object,
                // data-parallel over the candidate rows
                let dist = self.registry().distance(method);
                let mut out = vec![0.0f32; queries.len() * cols];
                {
                    let slots = SyncSlice::new(&mut out);
                    for (qi, q) in queries.iter().enumerate() {
                        parallel_for(cols, self.params.threads, |start, end| {
                            for c in start..end {
                                let doc = self.dataset.histogram(ids[c] as usize);
                                let d = match dist.distance(&self.dataset.embeddings, &doc, q) {
                                    Ok(v) => v as f32,
                                    Err(_) => f32::INFINITY,
                                };
                                // SAFETY: cell (qi, c) is owned by exactly
                                // this chunk.
                                unsafe { slots.write(qi * cols + c, d) };
                            }
                        });
                    }
                }
                out
            }
        }
    }

    /// Gather database rows `ids` into a standalone sub-CSR matrix (weights
    /// copied verbatim, so downstream sweeps are bit-identical).
    fn gather_rows(&self, ids: &[u32]) -> CsrMatrix {
        let db = &self.dataset.matrix;
        let mut indptr = Vec::with_capacity(ids.len() + 1);
        indptr.push(0usize);
        let mut indices: Vec<u32> = Vec::new();
        let mut data: Vec<f32> = Vec::new();
        for &u in ids {
            let (idx, w) = db.row(u as usize);
            indices.extend_from_slice(idx);
            data.extend_from_slice(w);
            indptr.push(indices.len());
        }
        CsrMatrix::from_raw(indptr, indices, data, db.ncols())
    }

    /// Per-pair fallback: score the query against every row through the
    /// registry's boxed [`Distance`] object, data-parallel over database
    /// rows.
    fn per_pair_row(&self, query: &Histogram, method: Method) -> Vec<f32> {
        let dist = self.registry().distance(method);
        self.per_pair_row_via(query, dist.as_ref())
    }

    /// One query row through a caller-supplied per-pair [`Distance`] object
    /// (lets callers bring their own metric / solver parameters).  A pair
    /// that fails to evaluate scores `+inf` so it can never fake a match.
    pub fn per_pair_row_via(&self, query: &Histogram, dist: &dyn Distance) -> Vec<f32> {
        let n = self.dataset.len();
        let mut out = vec![0.0f32; n];
        {
            let slots = SyncSlice::new(&mut out);
            parallel_for(n, self.params.threads, |start, end| {
                for u in start..end {
                    let doc = self.dataset.histogram(u);
                    let d = match dist.distance(&self.dataset.embeddings, &doc, query) {
                        Ok(v) => v as f32,
                        Err(_) => f32::INFINITY,
                    };
                    // SAFETY: index u is owned by exactly this chunk.
                    unsafe { slots.write(u, d) };
                }
            });
        }
        out
    }

    /// All-pairs asymmetric direction-A matrix `(n, n)`: row u = distances
    /// with query u.  Parallel over query blocks: each worker feeds blocks
    /// of [`EngineParams::batch_block`] CSR rows through the tiled
    /// multi-query Phase-1 kernel (vocabulary streamed once per block, not
    /// once per query) with a chunk-local [`PlanScratch`], then writes
    /// Phase-2 rows straight into the matrix — zero per-query heap
    /// allocations in steady state.  Per-pair methods are symmetric by
    /// construction, so their "asymmetric" matrix is the symmetric triangle
    /// sweep.
    pub fn all_pairs_asymmetric(&self, method: Method) -> Vec<f32> {
        if !method.is_linear_complexity() {
            let dist = self.registry().distance(method);
            return self.all_pairs_symmetric_via(dist.as_ref());
        }
        let n = self.dataset.len();
        let db = &self.dataset.matrix;
        let mut out = vec![0.0f32; n * n];
        match method {
            Method::Bow | Method::Wcd => {
                let m = self.dataset.embeddings.dim();
                let slots = SyncSlice::new(&mut out);
                parallel_for(n, self.params.threads, |start, end| {
                    for uq in start..end {
                        let q = self.dataset.histogram(uq);
                        // per-query rows computed serially inside the outer
                        // parallel sweep (no nested parallelism)
                        let row: Vec<f32> = match method {
                            Method::Bow => bow_distances_batch(&q, db, &self.bow_norms)
                                .into_iter()
                                .map(|d| d as f32)
                                .collect(),
                            _ => {
                                let qc =
                                    crate::approx::centroid(&self.dataset.embeddings, &q);
                                (0..n)
                                    .map(|u| {
                                        wcd_from_centroids(
                                            &qc,
                                            &self.centroids[u * m..(u + 1) * m],
                                        )
                                            as f32
                                    })
                                    .collect()
                            }
                        };
                        unsafe { slots.slice_mut(uq * n, (uq + 1) * n).copy_from_slice(&row) };
                    }
                });
            }
            Method::Rwmd | Method::Omr | Method::Act { .. } => {
                let params = PlanParams {
                    k: method.plan_k(),
                    metric: self.params.metric,
                    keep_d: false,
                    threads: 1,
                    kernel: self.params.kernel,
                };
                let bb = self.params.batch_block.max(1);
                let planner =
                    BatchPlanner::new(&self.dataset.embeddings, &self.vocab_sq_norms);
                let slots = SyncSlice::new(&mut out);
                parallel_for(n, self.params.threads, |start, end| {
                    let mut scratch = PlanScratch::new();
                    let mut plans: Vec<QueryPlan> = Vec::new();
                    let mut block: Vec<(&[u32], &[f32])> = Vec::with_capacity(bb);
                    let mut u0 = start;
                    while u0 < end {
                        let u1 = (u0 + bb).min(end);
                        block.clear();
                        for u in u0..u1 {
                            block.push(db.row(u));
                        }
                        planner.plan_rows_into(&block, params, &mut scratch, &mut plans);
                        for (i, plan) in plans.iter().enumerate() {
                            let uq = u0 + i;
                            // SAFETY: row uq is owned by exactly this chunk.
                            let row = unsafe { slots.slice_mut(uq * n, (uq + 1) * n) };
                            match method {
                                Method::Rwmd => rwmd_direction_a_into(plan, db, 1, row),
                                Method::Omr => omr_direction_a_into(plan, db, 1, row),
                                _ => act_direction_a_into(plan, db, 1, row),
                            }
                        }
                        u0 = u1;
                    }
                });
            }
            _ => unreachable!("per-pair methods handled above"),
        }
        out
    }

    /// All-pairs symmetric matrix: `max(A, Aᵀ)` over the asymmetric sweep
    /// (the paper's symmetric lower bound) for the LC methods; the per-pair
    /// measures are symmetric by construction, so only the upper triangle
    /// is evaluated and mirrored.
    pub fn all_pairs_symmetric(&self, method: Method) -> Vec<f32> {
        if !method.is_linear_complexity() {
            let dist = self.registry().distance(method);
            return self.all_pairs_symmetric_via(dist.as_ref());
        }
        let n = self.dataset.len();
        let mut a = self.all_pairs_asymmetric(method);
        if !matches!(method, Method::Bow | Method::Wcd) {
            // Data-parallel O(n²) symmetrization.  Safe partition: the cell
            // pair {(u,v), (v,u)} is read and written only by the worker
            // that owns row min(u,v), and rows are disjoint across chunks.
            let slots = SyncSlice::new(&mut a);
            parallel_for(n, self.params.threads, |start, end| {
                for u in start..end {
                    for v in (u + 1)..n {
                        unsafe {
                            let x = slots.get(u * n + v).max(slots.get(v * n + u));
                            slots.write(u * n + v, x);
                            slots.write(v * n + u, x);
                        }
                    }
                }
            });
        }
        a
    }

    /// All-pairs matrix through a caller-supplied *symmetric* per-pair
    /// [`Distance`] object: the upper triangle (plus diagonal) is computed
    /// data-parallel over rows and mirrored — half the evaluations of a
    /// full sweep, which matters for exact EMD / Sinkhorn.
    pub fn all_pairs_symmetric_via(&self, dist: &dyn Distance) -> Vec<f32> {
        let n = self.dataset.len();
        let mut out = vec![0.0f32; n * n];
        {
            let slots = SyncSlice::new(&mut out);
            parallel_for(n, self.params.threads, |start, end| {
                for u in start..end {
                    let q = self.dataset.histogram(u);
                    for v in u..n {
                        let doc = self.dataset.histogram(v);
                        let d = match dist.distance(&self.dataset.embeddings, &doc, &q) {
                            Ok(x) => x as f32,
                            Err(_) => f32::INFINITY,
                        };
                        // SAFETY: cell (u, v) with v >= u and its mirror
                        // (v, u) are written only by the worker owning row
                        // u, and rows are disjoint across chunks.
                        unsafe {
                            slots.write(u * n + v, d);
                            if v > u {
                                slots.write(v * n + u, d);
                            }
                        }
                    }
                }
            });
        }
        out
    }
}

/// A method bound to an [`LcEngine`] behind the [`BatchDistance`] trait —
/// what [`MethodRegistry::batch`] hands out and what the evaluation harness
/// iterates over.
///
/// Linear-complexity methods run the engine's Phase-1/Phase-2 pipeline
/// (governed by the engine's own `EngineParams`); per-pair fallback methods
/// evaluate through the *registry's* boxed [`Distance`] object, so a
/// registry configured with custom `SinkhornParams` or a different metric
/// is honored.
pub struct LcBatch {
    engine: Arc<LcEngine>,
    method: Method,
    /// `Some` for per-pair fallback methods: the registry-configured object.
    pair: Option<Box<dyn Distance>>,
}

impl LcBatch {
    /// Bind `method` to `engine`, using the engine's own registry for the
    /// per-pair fallback.
    pub fn new(engine: Arc<LcEngine>, method: Method) -> LcBatch {
        let registry = engine.registry();
        LcBatch::with_registry(engine, method, &registry)
    }

    /// Bind `method` to `engine`, drawing per-pair fallback objects from a
    /// caller-configured registry.
    pub fn with_registry(
        engine: Arc<LcEngine>,
        method: Method,
        registry: &MethodRegistry,
    ) -> LcBatch {
        let pair =
            if method.is_linear_complexity() { None } else { Some(registry.distance(method)) };
        LcBatch { engine, method, pair }
    }
}

impl BatchDistance for LcBatch {
    fn method(&self) -> Method {
        self.method
    }

    fn num_rows(&self) -> usize {
        self.engine.dataset().len()
    }

    fn distances(&self, query: &Histogram) -> EmdResult<Vec<f32>> {
        Ok(match &self.pair {
            Some(dist) => self.engine.per_pair_row_via(query, dist.as_ref()),
            None => self.engine.distances(query, self.method),
        })
    }

    fn distances_batch(&self, queries: &[Histogram]) -> EmdResult<Vec<f32>> {
        Ok(match &self.pair {
            // per-pair fallback: registry-configured object, row by row
            Some(dist) => {
                let mut out = Vec::with_capacity(queries.len() * self.num_rows());
                for q in queries {
                    out.extend_from_slice(&self.engine.per_pair_row_via(q, dist.as_ref()));
                }
                out
            }
            // LC methods: the engine's batched Phase-1 block pipeline
            None => self.engine.distances_batch(queries, self.method),
        })
    }

    fn all_pairs_symmetric(&self) -> EmdResult<Vec<f32>> {
        Ok(match &self.pair {
            Some(dist) => self.engine.all_pairs_symmetric_via(dist.as_ref()),
            None => self.engine.all_pairs_symmetric(self.method),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Embeddings;
    use crate::util::rng::Rng;

    fn tiny_dataset(seed: u64, n: usize, v: usize, m: usize, h: usize) -> Dataset {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..v * m).map(|_| rng.normal() as f32).collect();
        let emb = Embeddings::new(data, v, m);
        let hists: Vec<Histogram> = (0..n)
            .map(|_| {
                let idx = rng.sample_indices(v, h);
                Histogram::from_pairs(
                    idx.into_iter()
                        .map(|i| (i as u32, rng.range_f64(0.1, 1.0) as f32))
                        .collect(),
                )
            })
            .collect();
        let labels = (0..n as u16).map(|i| i % 3).collect();
        Dataset::new("tiny", emb, &hists, labels)
    }

    #[test]
    fn symmetric_matrix_is_symmetric_with_zero_diag() {
        let ds = tiny_dataset(1, 8, 24, 3, 5);
        let eng = LcEngine::new(std::sync::Arc::new(ds.clone()), EngineParams { threads: 2, ..Default::default() });
        for method in [Method::Rwmd, Method::Omr, Method::Act { k: 3 }, Method::Bow] {
            let m = eng.all_pairs_symmetric(method);
            let n = ds.len();
            let exact = !matches!(method, Method::Bow | Method::Wcd);
            for u in 0..n {
                assert!(m[u * n + u].abs() < 1e-5, "{method:?} diag {u}");
                for v in 0..n {
                    let (a, b) = (m[u * n + v], m[v * n + u]);
                    if exact {
                        // LC methods are symmetrized explicitly: bit-equal
                        assert_eq!(a, b, "{method:?} asym {u},{v}");
                    } else {
                        // BoW/WCD are mathematically symmetric but computed
                        // per-query with f32 norms: last-ulp differences ok
                        assert!((a - b).abs() < 1e-5, "{method:?} asym {u},{v}");
                    }
                }
            }
        }
    }

    #[test]
    fn chain_rwmd_le_act_on_symmetric_matrices() {
        let ds = tiny_dataset(2, 10, 30, 4, 6);
        let eng = LcEngine::new(std::sync::Arc::new(ds.clone()), EngineParams { threads: 2, ..Default::default() });
        let r = eng.all_pairs_symmetric(Method::Rwmd);
        let a2 = eng.all_pairs_symmetric(Method::Act { k: 2 });
        let a4 = eng.all_pairs_symmetric(Method::Act { k: 4 });
        for i in 0..r.len() {
            assert!(r[i] <= a2[i] + 1e-5);
            assert!(a2[i] <= a4[i] + 1e-5);
        }
    }

    #[test]
    fn single_query_symmetric_uses_direction_b() {
        let ds = tiny_dataset(3, 6, 20, 3, 4);
        let ds = std::sync::Arc::new(ds);
        let eng_sym = LcEngine::new(
            std::sync::Arc::clone(&ds),
            EngineParams { symmetric: true, threads: 1, ..Default::default() },
        );
        let eng_asym = LcEngine::new(
            std::sync::Arc::clone(&ds),
            EngineParams { symmetric: false, threads: 1, ..Default::default() },
        );
        let q = ds.histogram(0);
        let sym = eng_sym.distances(&q, Method::Rwmd);
        let asym = eng_asym.distances(&q, Method::Rwmd);
        for (s, a) in sym.iter().zip(&asym) {
            assert!(s >= a, "symmetric must dominate");
        }
    }

    #[test]
    fn distances_row_matches_all_pairs_row() {
        let ds = tiny_dataset(4, 7, 25, 3, 5);
        let eng = LcEngine::new(
            std::sync::Arc::new(ds.clone()),
            EngineParams { symmetric: false, threads: 2, ..Default::default() },
        );
        let all = eng.all_pairs_asymmetric(Method::Act { k: 2 });
        let row3 = eng.distances(&ds.histogram(3), Method::Act { k: 2 });
        let n = ds.len();
        for v in 0..n {
            assert!((all[3 * n + v] - row3[v]).abs() < 1e-6);
        }
    }

    #[test]
    fn per_pair_methods_run_through_the_engine() {
        let ds = tiny_dataset(5, 6, 20, 3, 4);
        let ds = std::sync::Arc::new(ds);
        let eng = LcEngine::new(std::sync::Arc::clone(&ds), EngineParams { threads: 2, ..Default::default() });
        let n = ds.len();
        for method in [Method::BowAdjusted, Method::Ict, Method::Sinkhorn, Method::Exact] {
            let row = eng.distances(&ds.histogram(0), method);
            assert_eq!(row.len(), n, "{method}");
            assert!(row.iter().all(|d| d.is_finite() && *d >= 0.0), "{method}");
        }
        // per-pair engine rows must agree with the registry's pair objects
        let registry = eng.registry();
        let exact = registry.distance(Method::Exact);
        let row = eng.distances(&ds.histogram(1), Method::Exact);
        for u in 0..n {
            let want = exact
                .distance(&ds.embeddings, &ds.histogram(u), &ds.histogram(1))
                .unwrap() as f32;
            assert!((row[u] - want).abs() < 1e-6, "doc {u}");
        }
    }

    #[test]
    fn per_pair_all_pairs_chain_vs_lc_bounds() {
        // ICT through the fallback must dominate LC-ACT which dominates
        // LC-RWMD, elementwise, on the symmetric matrices.
        let ds = tiny_dataset(6, 8, 24, 3, 5);
        let eng = LcEngine::new(std::sync::Arc::new(ds), EngineParams { threads: 2, ..Default::default() });
        let r = eng.all_pairs_symmetric(Method::Rwmd);
        let a = eng.all_pairs_symmetric(Method::Act { k: 3 });
        let i = eng.all_pairs_symmetric(Method::Ict);
        let e = eng.all_pairs_symmetric(Method::Exact);
        for x in 0..r.len() {
            assert!(r[x] <= a[x] + 1e-5, "RWMD > ACT at {x}");
            assert!(a[x] <= i[x] + 1e-5, "ACT > ICT at {x}");
            assert!(i[x] <= e[x] + 1e-4, "ICT > EMD at {x}");
        }
    }

    #[test]
    fn batch_honors_registry_sinkhorn_params() {
        use crate::approx::SinkhornParams;
        let ds = std::sync::Arc::new(tiny_dataset(8, 6, 20, 3, 4));
        let eng = std::sync::Arc::new(LcEngine::new(
            std::sync::Arc::clone(&ds),
            EngineParams { threads: 1, ..Default::default() },
        ));
        let loose = MethodRegistry::new(Metric::L2)
            .with_sinkhorn(SinkhornParams { lambda: 2.0, max_iters: 300, tol: 1e-9 });
        let tight = MethodRegistry::new(Metric::L2)
            .with_sinkhorn(SinkhornParams { lambda: 80.0, max_iters: 300, tol: 1e-9 });
        let q = ds.histogram(0);
        let rl = loose.batch(&eng, Method::Sinkhorn).distances(&q).unwrap();
        let rt = tight.batch(&eng, Method::Sinkhorn).distances(&q).unwrap();
        assert_ne!(rl, rt, "custom SinkhornParams must flow through batch objects");
    }

    #[test]
    fn subset_distances_match_full_sweep_bit_exactly() {
        let ds = std::sync::Arc::new(tiny_dataset(9, 12, 30, 4, 5));
        let eng = LcEngine::new(
            std::sync::Arc::clone(&ds),
            EngineParams { threads: 2, batch_block: 2, ..Default::default() },
        );
        let queries: Vec<Histogram> = (0..3).map(|u| ds.histogram(u)).collect();
        let ids: Vec<u32> = vec![1, 4, 5, 9, 11];
        let n = ds.len();
        for method in [
            Method::Rwmd,
            Method::Omr,
            Method::Act { k: 3 },
            Method::Bow,
            Method::Wcd,
            Method::Ict,
        ] {
            let full = eng.distances_batch(&queries, method);
            let sub = eng.distances_batch_subset(&queries, method, &ids);
            assert_eq!(sub.len(), queries.len() * ids.len(), "{method}");
            for qi in 0..queries.len() {
                for (c, &u) in ids.iter().enumerate() {
                    assert_eq!(
                        sub[qi * ids.len() + c],
                        full[qi * n + u as usize],
                        "{method} query {qi} doc {u}"
                    );
                }
            }
        }
        // full id range reproduces the whole matrix
        let all: Vec<u32> = (0..n as u32).collect();
        let full = eng.distances_batch(&queries, Method::Act { k: 2 });
        assert_eq!(eng.distances_batch_subset(&queries, Method::Act { k: 2 }, &all), full);
    }

    #[test]
    fn tiered_paths_default_to_exact_and_compressed_tier_scores() {
        let ds = std::sync::Arc::new(tiny_dataset(10, 8, 24, 3, 5));
        let exact_eng = LcEngine::new(
            std::sync::Arc::clone(&ds),
            EngineParams { threads: 2, ..Default::default() },
        );
        let comp_eng = LcEngine::new(
            std::sync::Arc::clone(&ds),
            EngineParams { threads: 2, compressed: CompressedKind::F16, ..Default::default() },
        );
        assert!(!exact_eng.compressed_active());
        assert!(comp_eng.compressed_active());
        let queries: Vec<Histogram> = (0..3).map(|u| ds.histogram(u)).collect();
        let method = Method::Act { k: 2 };
        // tiered(false) is bit-identical to the plain batch path
        assert_eq!(
            comp_eng.distances_batch_tiered(&queries, method, false),
            comp_eng.distances_batch(&queries, method)
        );
        // compressed rows are finite approximate scores of the right shape
        let c = comp_eng.distances_batch_tiered(&queries, method, true);
        let n = ds.len();
        assert_eq!(c.len(), queries.len() * n);
        assert!(c.iter().all(|d| d.is_finite()));
        // an engine without a tier serves exact rows for compressed requests
        assert_eq!(
            exact_eng.distances_batch_tiered(&queries, method, true),
            exact_eng.distances_batch(&queries, method)
        );
        // compressed subset rows restrict the compressed full sweep exactly
        let ids: Vec<u32> = vec![0, 2, 5, 7];
        let sub = comp_eng.distances_batch_subset_tiered(&queries, method, &ids, true);
        for qi in 0..queries.len() {
            for (ci, &u) in ids.iter().enumerate() {
                assert_eq!(sub[qi * ids.len() + ci], c[qi * n + u as usize]);
            }
        }
    }

    #[test]
    fn lc_batch_implements_batch_distance() {
        let ds = std::sync::Arc::new(tiny_dataset(7, 6, 20, 3, 4));
        let eng = std::sync::Arc::new(LcEngine::new(
            std::sync::Arc::clone(&ds),
            EngineParams { threads: 2, ..Default::default() },
        ));
        let registry = MethodRegistry::new(Metric::L2);
        let batch = registry.batch(&eng, Method::Act { k: 2 });
        assert_eq!(batch.method(), Method::Act { k: 2 });
        assert_eq!(batch.num_rows(), 6);
        let row = batch.distances(&ds.histogram(2)).unwrap();
        assert_eq!(row, eng.distances(&ds.histogram(2), Method::Act { k: 2 }));
        let m = batch.all_pairs_symmetric().unwrap();
        assert_eq!(m.len(), 36);
    }
}
