//! High-level LC engine: one-query-vs-database distance computation for
//! every method, plus the all-pairs symmetric evaluation used by the
//! accuracy experiments (paper Section 6).
//!
//! For all-pairs runs, the symmetric measure `max(m(a→b), m(b→a))` is
//! assembled from two asymmetric direction-A sweeps (document b scores
//! query a's sweep and vice versa), exactly how the paper evaluates — no
//! per-pair quadratic work.

use crate::approx::{bow_distances_batch, centroids_batch, wcd_from_centroids};
use std::sync::Arc;

use crate::core::{Dataset, Histogram, Metric};
use crate::util::threadpool::{parallel_for, SyncSlice};

use super::plan::{plan_query, PlanParams};
use super::transfers::{
    act_direction_a, omr_direction_a, rwmd_direction_a, rwmd_direction_b,
};

/// Distance measure selector for the engine / coordinator / CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// BoW cosine distance (baseline, no embeddings).
    Bow,
    /// Word centroid distance (baseline).
    Wcd,
    /// LC-RWMD (k = 1).
    Rwmd,
    /// LC-OMR (overlap-only capacity, top-2).
    Omr,
    /// LC-ACT with k-1 constrained iterations.
    Act { k: usize },
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        let ls = s.to_ascii_lowercase();
        match ls.as_str() {
            "bow" => return Some(Method::Bow),
            "wcd" => return Some(Method::Wcd),
            "rwmd" => return Some(Method::Rwmd),
            "omr" => return Some(Method::Omr),
            _ => {}
        }
        if let Some(rest) = ls.strip_prefix("act-") {
            // paper naming: ACT-j runs j Phase-2 iterations => k = j + 1
            if let Ok(j) = rest.parse::<usize>() {
                return Some(Method::Act { k: j + 1 });
            }
        }
        None
    }

    pub fn name(&self) -> String {
        match self {
            Method::Bow => "BoW".into(),
            Method::Wcd => "WCD".into(),
            Method::Rwmd => "RWMD".into(),
            Method::Omr => "OMR".into(),
            Method::Act { k } => format!("ACT-{}", k - 1),
        }
    }

    /// Phase-1 top-k requirement (0 = no plan needed).
    fn plan_k(&self) -> usize {
        match self {
            Method::Bow | Method::Wcd => 0,
            Method::Rwmd => 1,
            Method::Omr => 2,
            Method::Act { k } => (*k).max(1),
        }
    }
}

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineParams {
    pub metric: Metric,
    pub threads: usize,
    /// Also compute direction-B RWMD and take the max (single-query mode).
    pub symmetric: bool,
}

impl Default for EngineParams {
    fn default() -> Self {
        EngineParams {
            metric: Metric::L2,
            threads: crate::util::threadpool::default_threads(),
            symmetric: true,
        }
    }
}

/// The native (CPU data-parallel) LC engine over one database.
///
/// Owns a shared handle to the dataset plus the per-database precomputations
/// (BoW row norms, WCD centroids) so constructing it once and reusing it per
/// query is cheap — the coordinator caches one engine per dataset.
pub struct LcEngine {
    dataset: Arc<Dataset>,
    params: EngineParams,
    bow_norms: Vec<f32>,
    centroids: Vec<f64>,
}

impl LcEngine {
    pub fn new(dataset: Arc<Dataset>, params: EngineParams) -> LcEngine {
        LcEngine {
            bow_norms: dataset.matrix.row_l2_norms(),
            centroids: centroids_batch(&dataset.embeddings, &dataset.matrix),
            dataset,
            params,
        }
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    pub fn params(&self) -> &EngineParams {
        &self.params
    }

    /// Distances from one query histogram to every database row (direction
    /// A; plus max with direction-B RWMD when `symmetric` is set).
    pub fn distances(&self, query: &Histogram, method: Method) -> Vec<f32> {
        let db = &self.dataset.matrix;
        match method {
            Method::Bow => bow_distances_batch(query, db, &self.bow_norms)
                .into_iter()
                .map(|d| d as f32)
                .collect(),
            Method::Wcd => {
                let qc = crate::approx::centroid(&self.dataset.embeddings, query);
                let m = self.dataset.embeddings.dim();
                (0..db.nrows())
                    .map(|u| {
                        wcd_from_centroids(&qc, &self.centroids[u * m..(u + 1) * m]) as f32
                    })
                    .collect()
            }
            _ => {
                let keep_d = self.params.symmetric;
                let plan = plan_query(
                    &self.dataset.embeddings,
                    query,
                    PlanParams {
                        k: method.plan_k(),
                        metric: self.params.metric,
                        keep_d,
                        threads: self.params.threads,
                    },
                );
                let mut t = match method {
                    Method::Rwmd => rwmd_direction_a(&plan, db, self.params.threads),
                    Method::Omr => omr_direction_a(&plan, db, self.params.threads),
                    Method::Act { .. } => act_direction_a(&plan, db, self.params.threads),
                    _ => unreachable!(),
                };
                if keep_d {
                    let tb = rwmd_direction_b(&plan, db, self.params.threads);
                    for (a, b) in t.iter_mut().zip(tb) {
                        if b > *a {
                            *a = b;
                        }
                    }
                }
                t
            }
        }
    }

    /// All-pairs asymmetric direction-A matrix `(n, n)`: row u = distances
    /// with query u.  Parallel over queries (each query's Phase 1/2 is
    /// itself sequential here to avoid nested parallelism).
    pub fn all_pairs_asymmetric(&self, method: Method) -> Vec<f32> {
        let n = self.dataset.len();
        let db = &self.dataset.matrix;
        let mut out = vec![0.0f32; n * n];
        match method {
            Method::Bow | Method::Wcd => {
                let slots = SyncSlice::new(&mut out);
                parallel_for(n, self.params.threads, |start, end| {
                    for uq in start..end {
                        let q = self.dataset.histogram(uq);
                        let row = self.distances(&q, method);
                        unsafe { slots.slice_mut(uq * n, (uq + 1) * n).copy_from_slice(&row) };
                    }
                });
            }
            _ => {
                let k = method.plan_k();
                let slots = SyncSlice::new(&mut out);
                parallel_for(n, self.params.threads, |start, end| {
                    for uq in start..end {
                        let q = self.dataset.histogram(uq);
                        let plan = plan_query(
                            &self.dataset.embeddings,
                            &q,
                            PlanParams {
                                k,
                                metric: self.params.metric,
                                keep_d: false,
                                threads: 1,
                            },
                        );
                        let row = match method {
                            Method::Rwmd => rwmd_direction_a(&plan, db, 1),
                            Method::Omr => omr_direction_a(&plan, db, 1),
                            Method::Act { .. } => act_direction_a(&plan, db, 1),
                            _ => unreachable!(),
                        };
                        unsafe { slots.slice_mut(uq * n, (uq + 1) * n).copy_from_slice(&row) };
                    }
                });
            }
        }
        out
    }

    /// All-pairs symmetric matrix: `max(A, Aᵀ)` over the asymmetric sweep
    /// (the paper's symmetric lower bound).  BoW/WCD are already symmetric.
    pub fn all_pairs_symmetric(&self, method: Method) -> Vec<f32> {
        let n = self.dataset.len();
        let mut a = self.all_pairs_asymmetric(method);
        if !matches!(method, Method::Bow | Method::Wcd) {
            for u in 0..n {
                for v in (u + 1)..n {
                    let x = a[u * n + v].max(a[v * n + u]);
                    a[u * n + v] = x;
                    a[v * n + u] = x;
                }
            }
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Embeddings;
    use crate::util::rng::Rng;

    fn tiny_dataset(seed: u64, n: usize, v: usize, m: usize, h: usize) -> Dataset {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..v * m).map(|_| rng.normal() as f32).collect();
        let emb = Embeddings::new(data, v, m);
        let hists: Vec<Histogram> = (0..n)
            .map(|_| {
                let idx = rng.sample_indices(v, h);
                Histogram::from_pairs(
                    idx.into_iter()
                        .map(|i| (i as u32, rng.range_f64(0.1, 1.0) as f32))
                        .collect(),
                )
            })
            .collect();
        let labels = (0..n as u16).map(|i| i % 3).collect();
        Dataset::new("tiny", emb, &hists, labels)
    }

    #[test]
    fn method_parsing() {
        assert_eq!(Method::parse("bow"), Some(Method::Bow));
        assert_eq!(Method::parse("ACT-7"), Some(Method::Act { k: 8 }));
        assert_eq!(Method::parse("act-0"), Some(Method::Act { k: 1 }));
        assert_eq!(Method::parse("nope"), None);
        assert_eq!(Method::Act { k: 8 }.name(), "ACT-7");
    }

    #[test]
    fn symmetric_matrix_is_symmetric_with_zero_diag() {
        let ds = tiny_dataset(1, 8, 24, 3, 5);
        let eng = LcEngine::new(std::sync::Arc::new(ds.clone()), EngineParams { threads: 2, ..Default::default() });
        for method in [Method::Rwmd, Method::Omr, Method::Act { k: 3 }, Method::Bow] {
            let m = eng.all_pairs_symmetric(method);
            let n = ds.len();
            let exact = !matches!(method, Method::Bow | Method::Wcd);
            for u in 0..n {
                assert!(m[u * n + u].abs() < 1e-5, "{method:?} diag {u}");
                for v in 0..n {
                    let (a, b) = (m[u * n + v], m[v * n + u]);
                    if exact {
                        // LC methods are symmetrized explicitly: bit-equal
                        assert_eq!(a, b, "{method:?} asym {u},{v}");
                    } else {
                        // BoW/WCD are mathematically symmetric but computed
                        // per-query with f32 norms: last-ulp differences ok
                        assert!((a - b).abs() < 1e-5, "{method:?} asym {u},{v}");
                    }
                }
            }
        }
    }

    #[test]
    fn chain_rwmd_le_act_on_symmetric_matrices() {
        let ds = tiny_dataset(2, 10, 30, 4, 6);
        let eng = LcEngine::new(std::sync::Arc::new(ds.clone()), EngineParams { threads: 2, ..Default::default() });
        let r = eng.all_pairs_symmetric(Method::Rwmd);
        let a2 = eng.all_pairs_symmetric(Method::Act { k: 2 });
        let a4 = eng.all_pairs_symmetric(Method::Act { k: 4 });
        for i in 0..r.len() {
            assert!(r[i] <= a2[i] + 1e-5);
            assert!(a2[i] <= a4[i] + 1e-5);
        }
    }

    #[test]
    fn single_query_symmetric_uses_direction_b() {
        let ds = tiny_dataset(3, 6, 20, 3, 4);
        let ds = std::sync::Arc::new(ds);
        let eng_sym = LcEngine::new(
            std::sync::Arc::clone(&ds),
            EngineParams { symmetric: true, threads: 1, ..Default::default() },
        );
        let eng_asym = LcEngine::new(
            std::sync::Arc::clone(&ds),
            EngineParams { symmetric: false, threads: 1, ..Default::default() },
        );
        let q = ds.histogram(0);
        let sym = eng_sym.distances(&q, Method::Rwmd);
        let asym = eng_asym.distances(&q, Method::Rwmd);
        for (s, a) in sym.iter().zip(&asym) {
            assert!(s >= a, "symmetric must dominate");
        }
    }

    #[test]
    fn distances_row_matches_all_pairs_row() {
        let ds = tiny_dataset(4, 7, 25, 3, 5);
        let eng = LcEngine::new(
            std::sync::Arc::new(ds.clone()),
            EngineParams { symmetric: false, threads: 2, ..Default::default() },
        );
        let all = eng.all_pairs_asymmetric(Method::Act { k: 2 });
        let row3 = eng.distances(&ds.histogram(3), Method::Act { k: 2 });
        let n = ds.len();
        for v in 0..n {
            assert!((all[3 * n + v] - row3[v]).abs() < 1e-6);
        }
    }
}
