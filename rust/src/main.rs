//! `emdpar` CLI — the leader entrypoint.
//!
//! Subcommands:
//! * `datasets`        generate / persist / inspect datasets (Table 4)
//! * `search`          one query against a dataset, print top-ℓ
//! * `cascade`         two-stage search: RWMD prefilter + tighter rerank
//! * `index`           build / inspect / query the IVF pruning index
//! * `shard`           build / inspect / append to / query the sharded live corpus
//! * `eval`            reproduce the paper's accuracy tables (5, 6) & sweeps
//! * `serve`           run the TCP search server
//! * `node`            serve one shard of a file-backed dataset to a remote coordinator
//! * `trace`           dump a running server's span ring as Chrome trace-event JSON
//! * `telemetry`       snapshot a running server's workload telemetry + audited recall
//! * `artifacts-check` compile every artifact and cross-check PJRT vs native
//!
//! All method dispatch goes through the canonical [`Method`] enum and the
//! [`EngineBuilder`] from `emdpar::prelude`.

use std::path::Path;

use emdpar::data::{self, MnistConfig, TextConfig};
use emdpar::eval::{render_markdown, sweep_all_pairs, sweep_serving, sweep_subset};
use emdpar::prelude::{
    CascadeSpec, Config, EmdError, EmdResult, EngineBuilder, EngineParams, LcEngine, Method,
    Metric, ReactorServer, SearchRequest, Server, METHOD_SYNTAX,
};
use emdpar::runtime::{ArtifactEngine, Executor};
use emdpar::util::cli::CommandSpec;
use emdpar::util::logging;

fn main() {
    logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "--help" || args[0] == "help" {
        print_help();
        return;
    }
    let sub = args[0].clone();
    let rest = &args[1..];
    let result = match sub.as_str() {
        "datasets" => cmd_datasets(rest),
        "search" => cmd_search(rest),
        "cascade" => cmd_cascade(rest),
        "index" => cmd_index(rest),
        "shard" => cmd_shard(rest),
        "eval" => cmd_eval(rest),
        "serve" => cmd_serve(rest),
        "node" => cmd_node(rest),
        "trace" => cmd_trace(rest),
        "telemetry" => cmd_telemetry(rest),
        "artifacts-check" => cmd_artifacts_check(rest),
        other => {
            eprintln!("unknown subcommand '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "emdpar — low-complexity data-parallel EMD approximations\n\n\
         Usage: emdpar <subcommand> [options]\n\n\
         Subcommands:\n\
         \x20 datasets         generate/persist/inspect datasets (--help)\n\
         \x20 search           top-ℓ query against a dataset (--help)\n\
         \x20 cascade          RWMD prefilter + tighter rerank search (--help)\n\
         \x20 index            build / inspect / query the IVF pruning index (--help)\n\
         \x20 shard            build / inspect / append to / query the sharded live corpus (--help)\n\
         \x20 eval             reproduce accuracy tables / sweeps (--help)\n\
         \x20 serve            run the TCP search server (--help)\n\
         \x20 node             serve one dataset shard to a remote coordinator (--help)\n\
         \x20 trace            dump a server's span ring as Chrome trace-event JSON (--help)\n\
         \x20 telemetry        snapshot a server's workload telemetry + audited recall (--help)\n\
         \x20 artifacts-check  compile artifacts, verify PJRT == native\n"
    );
}

fn common_opts(spec: CommandSpec) -> CommandSpec {
    spec.opt("dataset", "synth-mnist:1000", "dataset: <file.bin> | synth-mnist[:n] | synth-text[:n]")
        .opt("config", "", "JSON config file (CLI flags override it)")
        .opt("method", "", METHOD_SYNTAX)
        .opt("threads", "", "worker threads")
        .opt("backend", "", "native | artifact")
        .opt("topl", "", "results per query")
        .opt("nlist", "", "enable the IVF pruning index with this many lists (0 disables)")
        .opt(
            "nprobe",
            "",
            "index lists probed per query (needs --nlist or a config index; >= nlist: exhaustive)",
        )
        .opt("kernel", "", "force the SIMD kernel backend: scalar | avx2 | avx512")
        .opt(
            "compressed",
            "",
            "stage-1 residency tier: none | f16 (exact-f32 rerank keeps results exact)",
        )
}

fn build_config(parsed: &emdpar::util::cli::Parsed) -> EmdResult<Config> {
    let mut cfg = match parsed.opt_str("config") {
        Some(path) if !path.is_empty() => Config::from_file(Path::new(path))?,
        _ => Config::default(),
    };
    cfg.apply_cli(parsed)?;
    Ok(cfg)
}

// ---------------------------------------------------------------------------

fn cmd_datasets(args: &[String]) -> EmdResult<()> {
    let spec = CommandSpec::new("datasets", "generate / persist / inspect datasets")
        .opt("kind", "mnist", "mnist | text")
        .opt("n", "1000", "number of items")
        .opt("background", "0", "MNIST background mass fraction (Table 6)")
        .opt("vocab", "8000", "text vocabulary size")
        .opt("dim", "64", "text embedding dimension")
        .opt("seed", "42", "generator seed")
        .opt("out", "", "write dataset to this .bin file")
        .flag("stats", "print Table-4 style properties");
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("emdpar"));
        return Ok(());
    }
    let p = spec.parse(args)?;
    let ds = match p.str("kind") {
        "mnist" => data::generate_mnist(&MnistConfig {
            n: p.usize("n")?,
            background: p.f64("background")? as f32,
            seed: p.usize("seed")? as u64,
            ..Default::default()
        }),
        "text" => data::generate_text(&TextConfig {
            n: p.usize("n")?,
            vocab: p.usize("vocab")?,
            dim: p.usize("dim")?,
            seed: p.usize("seed")? as u64,
            ..Default::default()
        }),
        other => return Err(EmdError::parse("dataset kind", other, "mnist | text")),
    };
    let st = ds.stats();
    println!(
        "{}: n={} avg_h={:.1} vocab={} used_vocab={} m={} classes={}",
        ds.name, st.n, st.avg_h, st.vocab_size, st.used_vocab, st.dim, st.classes
    );
    if p.flag("stats") {
        println!(
            "| {} | {} | {:.1} | {} | {} |   (paper Table 4 row format)",
            ds.name, st.n, st.avg_h, st.vocab_size, st.used_vocab
        );
    }
    if let Some(out) = p.opt_str("out") {
        if !out.is_empty() {
            data::save(&ds, Path::new(out))?;
            println!("wrote {out}");
        }
    }
    Ok(())
}

fn cmd_search(args: &[String]) -> EmdResult<()> {
    let spec = common_opts(CommandSpec::new("search", "top-ℓ query against a dataset"))
        .opt("id", "0", "query by database row id");
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("emdpar"));
        return Ok(());
    }
    let p = spec.parse(args)?;
    let cfg = build_config(&p)?;
    let method = cfg.method;
    let l = cfg.topl;
    let engine = EngineBuilder::from_config(cfg).build_search()?;
    let id = p.usize("id")?;
    emdpar::emd_ensure!(id < engine.num_docs(), "--id out of range");
    let query = engine.doc_histogram(id)?;
    // the one composable entry point: method/ℓ/nprobe resolve through the
    // query planner (index pruning and shard fan-out compose automatically)
    let request = SearchRequest::query(query).method(method).topl(l);
    let response = engine.execute(&request)?;
    println!("plan: {}", response.plan.describe());
    println!(
        "query id={id} (label {}) via {} — top-{l}:",
        engine.doc_label(id)?,
        method.name()
    );
    let res = &response.results[0];
    for (rank, (&(d, hit), &lab)) in res.hits.iter().zip(&res.labels).enumerate() {
        println!("  #{:<3} id={hit:<6} label={lab:<4} distance={d:.6}", rank + 1);
    }
    let m = engine.metrics();
    println!(
        "latency: mean {:.1} us over {} distance evals",
        m.mean_latency_us(),
        m.distance_evals.load(std::sync::atomic::Ordering::Relaxed)
    );
    Ok(())
}

fn cmd_cascade(args: &[String]) -> EmdResult<()> {
    // deliberately NOT common_opts: stage 1 is always LC-RWMD, so
    // --method/--backend would be accepted-but-ignored noise.  --nlist /
    // --nprobe compose the cascade with the IVF index, and a sharded config
    // file composes it with the fan-out — all through one SearchRequest.
    let spec = CommandSpec::new(
        "cascade",
        "two-stage search: LC-RWMD prefilter, tighter rerank on survivors",
    )
    .opt("dataset", "synth-mnist:1000", "dataset: <file.bin> | synth-mnist[:n] | synth-text[:n]")
    .opt("config", "", "JSON config file (CLI flags override it)")
    .opt("threads", "", "worker threads")
    .opt("topl", "", "results per query")
    .opt("id", "0", "query by database row id")
    .opt("rerank", "emd", "stage-2 measure: omr | act-<j> | ict | sinkhorn | emd")
    .opt("overfetch", "8", "stage-1 candidates = overfetch x topl")
    .opt("nlist", "", "enable the IVF pruning index for stage 1 (0 disables)")
    .opt("nprobe", "", "index lists probed in stage 1 (needs --nlist or a config index)")
    .flag(
        "certified",
        "force full stage-1 coverage so the Theorem-2 certificate is global",
    );
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("emdpar"));
        return Ok(());
    }
    let p = spec.parse(args)?;
    let cfg = build_config(&p)?;
    let l = cfg.topl;
    let rerank = Method::parse(p.str("rerank"))?;
    let overfetch = p.usize("overfetch")?.max(1);
    // match the legacy cascade CLI: asymmetric (direction-A) stage-1 RWMD
    let engine = EngineBuilder::from_config(cfg).symmetric(false).build_search()?;
    let id = p.usize("id")?;
    emdpar::emd_ensure!(id < engine.num_docs(), "--id out of range");
    let query = engine.doc_histogram(id)?;
    let request = SearchRequest::query(query).topl(l).cascade(
        CascadeSpec::new(rerank).overfetch(overfetch).certified(p.flag("certified")),
    );
    let response = engine.execute(&request)?;
    println!("plan: {}", response.plan.describe());
    println!(
        "cascade: RWMD prefilter -> {} rerank, top-{l} (overfetch {overfetch}, \
         reranked {}, certified: {})",
        rerank.name(),
        response.stats.reranked,
        response.stats.certified[0]
    );
    let res = &response.results[0];
    for (rank, (&(d, hit), &lab)) in res.hits.iter().zip(&res.labels).enumerate() {
        println!("  #{:<3} id={hit:<6} label={lab:<4} distance={d:.6}", rank + 1);
    }
    Ok(())
}

fn cmd_index(args: &[String]) -> EmdResult<()> {
    use emdpar::index::{
        dataset_fingerprint, load_index, load_index_for, pruned_search, save_index, sidecar_path,
        IvfIndex,
    };
    use emdpar::prelude::IndexParams;

    let spec = CommandSpec::new("index", "build / inspect / query the IVF pruning index")
        .opt("op", "build", "build | info | search")
        .opt("dataset", "synth-text:1000", "dataset: <file.bin> | synth-mnist[:n] | synth-text[:n]")
        .opt("config", "", "JSON config file (CLI flags override it)")
        .opt("threads", "", "worker threads")
        .opt("file", "", "EMDX index file (default: <dataset>.emdx for file datasets)")
        .opt("nlist", "64", "inverted lists to train")
        .opt("nprobe", "8", "lists to probe (search)")
        .opt("train-iters", "10", "Lloyd iterations")
        .opt("seed", "42", "k-means++ seed")
        .opt("min-points", "2", "minimum points per list (caps nlist)")
        .opt("method", "", METHOD_SYNTAX)
        .opt("topl", "", "results per query (search)")
        .opt("id", "0", "query by database row id (search)");
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("emdpar"));
        return Ok(());
    }
    let p = spec.parse(args)?;
    let op = p.str("op").to_string();

    // the explicit --file, else the dataset's sidecar path
    let index_file = |cfg: &Config| -> Option<std::path::PathBuf> {
        match p.opt_str("file") {
            Some(f) if !f.is_empty() => Some(std::path::PathBuf::from(f)),
            _ => match &cfg.dataset {
                emdpar::prelude::DatasetSpec::File(path) => Some(sidecar_path(path)),
                _ => None,
            },
        }
    };

    if op == "info" {
        // info only needs the file; a dataset (if given as a file) verifies
        // the fingerprint
        let cfg = build_config(&p)?;
        let file = index_file(&cfg)
            .ok_or_else(|| EmdError::config("index info needs --file (or a file dataset)"))?;
        let ix = load_index(&file)?;
        let sizes = ix.list_sizes();
        println!(
            "{file:?}: {} lists over {} docs (dim {}), fingerprint {:#018x}",
            ix.nlist(),
            ix.num_points(),
            ix.dim(),
            ix.fingerprint()
        );
        println!(
            "list sizes: min {} / mean {:.1} / max {}",
            sizes.iter().copied().min().unwrap_or(0),
            ix.num_points() as f64 / ix.nlist() as f64,
            sizes.iter().copied().max().unwrap_or(0)
        );
        if matches!(&cfg.dataset, emdpar::prelude::DatasetSpec::File(_)) {
            let ds = cfg.load_dataset()?;
            let fp = dataset_fingerprint(&ds);
            println!(
                "dataset fingerprint {fp:#018x}: {}",
                if fp == ix.fingerprint() { "MATCH" } else { "STALE — rebuild" }
            );
        }
        return Ok(());
    }

    let cfg = build_config(&p)?;
    let ds = std::sync::Arc::new(cfg.load_dataset()?);
    let fp = dataset_fingerprint(&ds);
    let engine: LcEngine =
        EngineBuilder::from_config(cfg.clone()).dataset(std::sync::Arc::clone(&ds)).build_lc()?;
    let params = IndexParams {
        nlist: p.usize("nlist")?.max(1),
        nprobe: p.usize("nprobe")?.max(1),
        train_iters: p.usize("train-iters")?.max(1),
        seed: p.usize("seed")? as u64,
        min_points_per_list: p.usize("min-points")?.max(1),
    };

    match op.as_str() {
        "build" => {
            let ix = IvfIndex::train(
                engine.wcd_centroids(),
                ds.embeddings.dim(),
                &params,
                cfg.threads,
                fp,
            )?;
            let sizes = ix.list_sizes();
            println!(
                "trained {} lists over {} docs (requested nlist {}, min/mean/max list {} / {:.1} / {})",
                ix.nlist(),
                ix.num_points(),
                params.nlist,
                sizes.iter().copied().min().unwrap_or(0),
                ix.num_points() as f64 / ix.nlist() as f64,
                sizes.iter().copied().max().unwrap_or(0)
            );
            match index_file(&cfg) {
                Some(file) => {
                    save_index(&ix, &file)?;
                    println!("wrote {file:?}");
                }
                None => println!(
                    "synthetic dataset: pass --file to persist the index (nothing written)"
                ),
            }
            Ok(())
        }
        "search" => {
            let ix = match index_file(&cfg) {
                Some(file) if file.exists() => {
                    let ix = load_index_for(&file, fp)?;
                    println!("loaded {file:?}");
                    ix
                }
                _ => {
                    println!("no index file; training in memory");
                    IvfIndex::train(
                        engine.wcd_centroids(),
                        ds.embeddings.dim(),
                        &params,
                        cfg.threads,
                        fp,
                    )?
                }
            };
            let id = p.usize("id")?;
            emdpar::emd_ensure!(id < ds.len(), "--id out of range");
            let query = ds.histogram(id);
            let method = cfg.method;
            let l = cfg.topl;
            let res = pruned_search(&engine, &ix, &query, method, l, params.nprobe)?;
            println!(
                "query id={id} via {} — top-{l} over {} candidates ({} of {} lists probed, \
                 {:.1}% of the database pruned):",
                method.name(),
                res.candidates,
                res.lists_probed,
                ix.nlist(),
                100.0 * (1.0 - res.candidates as f64 / ds.len() as f64)
            );
            for (rank, &(d, hit)) in res.hits.iter().enumerate() {
                println!(
                    "  #{:<3} id={hit:<6} label={:<4} distance={d:.6}",
                    rank + 1,
                    ds.labels[hit]
                );
            }
            Ok(())
        }
        other => Err(EmdError::parse("index op", other, "build | info | search")),
    }
}

fn cmd_shard(args: &[String]) -> EmdResult<()> {
    use emdpar::index::sidecar_path;
    use emdpar::prelude::{DatasetSpec, ShardParams};
    use emdpar::shard::load_manifest;

    let spec = CommandSpec::new(
        "shard",
        "build / inspect / append to / query the sharded live corpus",
    )
    .opt("op", "build", "build | info | append | search")
    .opt("dataset", "synth-text:1000", "dataset: <file.bin> | synth-mnist[:n] | synth-text[:n]")
    .opt("config", "", "JSON config file (CLI flags override it)")
    .opt("threads", "", "worker threads")
    .opt("shards", "", "shard count at build time (default 4, or the config's)")
    .opt(
        "max-docs",
        "",
        "appends open a fresh shard once every shard holds this many docs",
    )
    .opt("file", "", "EMDX v2 manifest file (default: <dataset>.emdx for file datasets)")
    .opt("nlist", "", "train a per-shard IVF index with this many lists (0 disables)")
    .opt(
        "nprobe",
        "",
        "lists probed per shard per query (needs --nlist; >= every shard's nlist: exhaustive)",
    )
    .opt("train-iters", "", "Lloyd iterations (per-shard index training)")
    .opt("seed", "", "k-means++ seed (index training)")
    .opt("min-points", "", "minimum points per list (caps each shard's nlist)")
    .opt("method", "", METHOD_SYNTAX)
    .opt("topl", "", "results per query (search)")
    .opt("id", "0", "query by live-corpus document id (search)")
    .opt("from", "", "append: EMD1 dataset file whose rows are appended (same vocabulary)");
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("emdpar"));
        return Ok(());
    }
    let p = spec.parse(args)?;
    let op = p.str("op").to_string();

    if op == "info" {
        // info reads the manifest alone; a file dataset verifies freshness
        let cfg = build_config(&p)?;
        let file = match p.opt_str("file") {
            Some(f) if !f.is_empty() => std::path::PathBuf::from(f),
            _ => match &cfg.dataset {
                DatasetSpec::File(path) => sidecar_path(path),
                _ => {
                    return Err(EmdError::config(
                        "shard info needs --file (or a file dataset)",
                    ))
                }
            },
        };
        let man = load_manifest(&file)?;
        println!(
            "{file:?}: {} shards over {} docs (append policy: fresh shard past {} docs), \
             corpus fingerprint {:#018x}",
            man.shards.len(),
            man.num_docs(),
            man.max_docs_per_shard,
            man.corpus_fingerprint
        );
        for (s, sh) in man.shards.iter().enumerate() {
            match &sh.index {
                Some(ix) => println!(
                    "  shard {s}: {} docs ({} appended), {} lists over dim {}",
                    sh.globals.len(),
                    sh.appended,
                    ix.nlist(),
                    ix.dim()
                ),
                None => println!(
                    "  shard {s}: {} docs ({} appended), exhaustive",
                    sh.globals.len(),
                    sh.appended
                ),
            }
        }
        if let DatasetSpec::File(_) = &cfg.dataset {
            let ds = cfg.load_dataset()?;
            let fp = emdpar::index::dataset_fingerprint(&ds);
            println!(
                "dataset fingerprint {fp:#018x}: {}",
                if fp == man.corpus_fingerprint { "MATCH" } else { "STALE — rebuild" }
            );
        }
        return Ok(());
    }

    // empty defaults keep config-file values authoritative: only a flag the
    // user actually passed overrides them
    let passed = |name: &str| p.opt_str(name).filter(|s| !s.is_empty()).is_some();
    let mut cfg = build_config(&p)?;
    let mut sp = cfg.sharded.unwrap_or_default();
    if passed("shards") {
        sp.shards = p.usize("shards")?.max(1);
    }
    if passed("max-docs") {
        sp.max_docs_per_shard = p.usize("max-docs")?.max(1);
    }
    cfg.sharded = Some(sp);
    if let Some(ixp) = &mut cfg.index {
        // --nlist/--nprobe flow through apply_cli; the training knobs are
        // subcommand-local
        if passed("train-iters") {
            ixp.train_iters = p.usize("train-iters")?.max(1);
        }
        if passed("seed") {
            ixp.seed = p.usize("seed")? as u64;
        }
        if passed("min-points") {
            ixp.min_points_per_list = p.usize("min-points")?.max(1);
        }
    }
    let method = cfg.method;
    let l = cfg.topl;
    let engine = EngineBuilder::from_config(cfg).build_search()?;
    let print_shards = |engine: &emdpar::prelude::SearchEngine| {
        for (s, st) in engine.shard_stats().unwrap_or_default().iter().enumerate() {
            match st.nlist {
                Some(nlist) => println!(
                    "  shard {s}: {} docs ({} appended), {nlist} lists \
                     (min/max list {} / {})",
                    st.docs, st.appended, st.min_list, st.max_list
                ),
                None => println!(
                    "  shard {s}: {} docs ({} appended), exhaustive",
                    st.docs, st.appended
                ),
            }
        }
    };

    match op.as_str() {
        "build" => {
            println!(
                "built {} shards over {} docs:",
                engine.shard_stats().map(|s| s.len()).unwrap_or(0),
                engine.num_docs()
            );
            print_shards(&engine);
            if engine.persist_shards()? {
                println!("wrote dataset + EMDX v2 manifest sidecar");
            } else {
                println!("synthetic dataset: nothing persisted (use a file dataset)");
            }
            Ok(())
        }
        "append" => {
            let from = match p.opt_str("from") {
                Some(f) if !f.is_empty() => f,
                _ => return Err(EmdError::config("shard append needs --from <file.bin>")),
            };
            let extra = data::load(Path::new(from))?;
            emdpar::emd_ensure!(
                extra.embeddings == engine.dataset().embeddings,
                "--from dataset '{}' uses a different vocabulary than the corpus",
                extra.name
            );
            let docs: Vec<_> = (0..extra.len()).map(|u| extra.histogram(u)).collect();
            let outcome = engine.add_docs(&docs, &extra.labels)?;
            println!(
                "appended {} docs (ids {}..{}, {} fresh shard(s) opened); corpus now {} docs:",
                outcome.ids.len(),
                outcome.ids.first().copied().unwrap_or(0),
                outcome.ids.last().copied().unwrap_or(0),
                outcome.opened,
                engine.num_docs()
            );
            print_shards(&engine);
            Ok(())
        }
        "search" => {
            let id = p.usize("id")?;
            emdpar::emd_ensure!(id < engine.num_docs(), "--id out of range");
            let query = engine.doc_histogram(id)?;
            let response =
                engine.execute(&SearchRequest::query(query).method(method).topl(l))?;
            let res = &response.results[0];
            println!("plan: {}", response.plan.describe());
            println!("query id={id} via {} — top-{l} over the sharded corpus:", method.name());
            for (rank, (&(d, hit), &lab)) in res.hits.iter().zip(&res.labels).enumerate() {
                println!("  #{:<3} id={hit:<6} label={lab:<4} distance={d:.6}", rank + 1);
            }
            let m = engine.metrics();
            println!(
                "fan-out: {} shard dispatch(es), merge {} us total, pruned fraction {:.3}",
                m.shard_batches.load(std::sync::atomic::Ordering::Relaxed),
                m.merge_us(),
                m.pruned_fraction()
            );
            print_shards(&engine);
            Ok(())
        }
        other => Err(EmdError::parse("shard op", other, "build | info | append | search")),
    }
}

fn cmd_eval(args: &[String]) -> EmdResult<()> {
    let spec = common_opts(CommandSpec::new(
        "eval",
        "reproduce accuracy/runtime experiments (Tables 5-6, Fig. 8 protocol)",
    ))
    .opt(
        "methods",
        "bow,rwmd,omr,act-1,act-3,act-7",
        "comma-separated method list (sinkhorn and emd are valid too)",
    )
    .opt("ls", "1,16,128", "comma-separated top-ℓ values")
    .opt("subset", "0", "query only the first N docs (0 = all-pairs)")
    .flag(
        "serving",
        "dispatch through the query planner (SearchRequest): honors --nlist/--nprobe \
         and a sharded config; 'pairs' reports candidates actually scored",
    );
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("emdpar"));
        return Ok(());
    }
    let p = spec.parse(args)?;
    let cfg = build_config(&p)?;
    let ds = std::sync::Arc::new(cfg.load_dataset()?);
    let methods = Method::parse_list(p.str("methods"))?;
    let ls = p.usize_list("ls")?;
    let subset = p.usize("subset")?;
    if p.flag("serving") {
        let nq = if subset > 0 { subset } else { 64 };
        let engine = EngineBuilder::from_config(cfg)
            .dataset(std::sync::Arc::clone(&ds))
            .build_search()?;
        let rows = sweep_serving(&engine, &methods, &ls, nq)?;
        println!(
            "{}",
            render_markdown(&format!("{} serving path (nq={nq})", ds.name), &rows)
        );
        return Ok(());
    }
    let params = EngineParams {
        metric: Metric::L2,
        threads: cfg.threads,
        symmetric: cfg.symmetric,
        batch_block: cfg.batch_block,
        kernel: cfg.kernel,
        compressed: cfg.compressed,
    };
    let rows = if subset > 0 {
        sweep_subset(&ds, subset, &methods, &ls, params)?
    } else {
        sweep_all_pairs(&ds, &methods, &ls, params)?
    };
    println!("{}", render_markdown(&format!("{} (n={})", ds.name, ds.len()), &rows));
    Ok(())
}

fn cmd_serve(args: &[String]) -> EmdResult<()> {
    let spec = common_opts(CommandSpec::new("serve", "run the TCP search server"))
        .opt("listen", "", "bind address (default from config)")
        .opt(
            "runtime",
            "reactor",
            "serving runtime: 'reactor' (event loop) or 'threads' (legacy)",
        )
        .opt("reactors", "", "reactor threads (default from config)")
        .opt("max-inflight", "", "admission budget: searches in flight before shedding")
        .opt("deadline-ms", "", "default per-request deadline, ms (0 = none)")
        .opt("idle-timeout-ms", "", "close idle connections after this many ms (0 = never)")
        .opt("max-line-bytes", "", "hard request-line length cap in bytes")
        .opt(
            "slow-query-us",
            "",
            "WARN-log requests slower than this many µs with their per-stage \
             breakdown (0 = off; EMDPAR_SLOW_QUERY_US overrides)",
        )
        .opt("trace-buffer", "", "span ring capacity in records (~40 bytes each, min 16)")
        .opt(
            "metrics-addr",
            "",
            "also serve Prometheus text at http://<addr>/metrics plus \
             /healthz and /readyz health probes (empty = off)",
        )
        .opt(
            "telemetry-window-ms",
            "",
            "sliding telemetry window duration, ms (0 = telemetry off)",
        )
        .opt(
            "audit-sample",
            "",
            "replay 1-in-N served queries at full probe for online recall \
             auditing (0 = off)",
        )
        .opt(
            "telemetry-out",
            "",
            "on graceful shutdown (SIGINT/SIGTERM, reactor runtime), flush \
             a final telemetry+audit JSON snapshot to this file",
        )
        .opt(
            "corpus-shards",
            "",
            "serve the sharded live corpus with this many shards (0 = monolithic)",
        )
        .opt(
            "topology",
            "",
            "topology manifest mapping shard ids to `emdpar node` replicas; \
             enables remote fan-out (needs --corpus-shards or a 'shard' config)",
        )
        .opt("shard-timeout-ms", "", "per-remote-shard deadline, ms")
        .opt(
            "hedge-ms",
            "",
            "hedge delay before racing a second replica, ms (0 = no hedging; \
             adapts toward the observed p99 once warmed up)",
        )
        .opt("remote-pool", "", "pooled connections kept per replica endpoint")
        .opt("remote-retries", "", "extra attempts per shard dispatch after a failure");
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("emdpar"));
        return Ok(());
    }
    let p = spec.parse(args)?;
    let mut cfg = match p.opt_str("config") {
        Some(path) if !path.is_empty() => Config::from_file(Path::new(path))?,
        _ => Config::default(),
    };
    // --corpus-shards must land before apply_cli: validation there rejects
    // a --topology without a sharded corpus to fan out over
    if !p.str("corpus-shards").is_empty() {
        cfg.sharded = match p.usize("corpus-shards")? {
            0 => None,
            n => {
                let mut sp = cfg.sharded.unwrap_or_default();
                sp.shards = n;
                Some(sp)
            }
        };
    }
    cfg.apply_cli(&p)?;
    if let Some(listen) = p.opt_str("listen") {
        if !listen.is_empty() {
            cfg.listen = listen.to_string();
        }
    }
    // empty string = "keep the config/default value" (flags override config)
    if !p.str("reactors").is_empty() {
        cfg.serve.reactors = p.usize("reactors")?;
    }
    if !p.str("max-inflight").is_empty() {
        cfg.serve.max_inflight = p.usize("max-inflight")?;
    }
    if !p.str("deadline-ms").is_empty() {
        cfg.serve.deadline_ms = p.usize("deadline-ms")? as u64;
    }
    if !p.str("idle-timeout-ms").is_empty() {
        cfg.serve.idle_timeout_ms = p.usize("idle-timeout-ms")? as u64;
    }
    if !p.str("max-line-bytes").is_empty() {
        cfg.serve.max_line_bytes = p.usize("max-line-bytes")?;
    }
    if !p.str("slow-query-us").is_empty() {
        cfg.serve.slow_query_us = p.usize("slow-query-us")? as u64;
    }
    if !p.str("trace-buffer").is_empty() {
        cfg.serve.trace_buffer = p.usize("trace-buffer")?;
    }
    if !p.str("telemetry-window-ms").is_empty() {
        cfg.serve.telemetry_window_ms = p.usize("telemetry-window-ms")? as u64;
    }
    if !p.str("audit-sample").is_empty() {
        cfg.serve.audit_sample = p.usize("audit-sample")? as u64;
    }
    cfg.validate()?;
    let runtime = p.str("runtime").to_string();
    let listen = cfg.listen.clone();
    let maddr = p.opt_str("metrics-addr").filter(|s| !s.is_empty()).map(String::from);
    let telemetry_out = p.opt_str("telemetry-out").filter(|s| !s.is_empty()).map(String::from);
    let engine = EngineBuilder::from_config(cfg).build_search()?;
    println!(
        "dataset '{}' ({} docs) ready; listening on {listen} ({runtime} runtime)",
        engine.dataset().name,
        engine.dataset().len()
    );
    match runtime.as_str() {
        "reactor" => {
            let server = ReactorServer::bind(engine, &listen)?;
            spawn_obs(maddr.as_deref(), server.engine(), Some(server.ready_probe()))?;
            // graceful SIGINT/SIGTERM: stop accepting, drain the reactors,
            // then flush the final telemetry snapshot before exiting
            emdpar::serve::sys::arm_shutdown_signals();
            server.serve_until(emdpar::serve::sys::shutdown_flag())?;
            let engine = std::sync::Arc::clone(server.engine());
            drop(server); // joins the reactor threads
            flush_telemetry_snapshot(&engine, telemetry_out.as_deref())
        }
        "threads" => {
            let server = Server::bind(engine, &listen)?;
            let probe_engine = std::sync::Arc::clone(server.engine());
            let probe: emdpar::obs::http::ReadyProbe = std::sync::Arc::new(move || {
                if probe_engine.ready() {
                    Ok(())
                } else {
                    Err("not ready: corpus empty or index untrained".to_string())
                }
            });
            spawn_obs(maddr.as_deref(), server.engine(), Some(probe))?;
            server.serve()
        }
        other => Err(EmdError::config(format!(
            "unknown --runtime '{other}' (expected 'reactor' or 'threads')"
        ))),
    }
}

/// Spawn the metrics/health HTTP listener when `--metrics-addr` is set.
fn spawn_obs(
    maddr: Option<&str>,
    engine: &std::sync::Arc<emdpar::prelude::SearchEngine>,
    ready: Option<emdpar::obs::http::ReadyProbe>,
) -> EmdResult<()> {
    let Some(maddr) = maddr else { return Ok(()) };
    let engine = std::sync::Arc::clone(engine);
    let render: std::sync::Arc<dyn Fn() -> String + Send + Sync> =
        std::sync::Arc::new(move || emdpar::obs::prom::render_engine(&engine));
    let (bound, _handle) = emdpar::obs::http::spawn_listener(maddr, render, ready)?;
    println!("metrics: http://{bound}/metrics (Prometheus text 0.0.4; health: /healthz, /readyz)");
    Ok(())
}

/// Write the final `{"telemetry":…,"audit":…}` snapshot on graceful
/// shutdown so a scrape gap at exit never loses the last window.
fn flush_telemetry_snapshot(
    engine: &emdpar::prelude::SearchEngine,
    path: Option<&str>,
) -> EmdResult<()> {
    let Some(path) = path else { return Ok(()) };
    let snap = emdpar::util::json::Json::obj(vec![
        ("telemetry", engine.telemetry().snapshot().to_json()),
        ("audit", engine.auditor().to_json()),
    ]);
    std::fs::write(path, snap.to_string_pretty() + "\n")?;
    eprintln!("wrote final telemetry snapshot to {path}");
    Ok(())
}

fn cmd_node(args: &[String]) -> EmdResult<()> {
    let spec = common_opts(CommandSpec::new(
        "node",
        "serve one shard of a file-backed dataset to a remote coordinator",
    ))
    .opt("shard", "0", "this node's shard id (0-based row-range slice of the dataset)")
    .opt("of", "1", "total shard count in the topology")
    .opt("listen", "", "bind address (default from config)")
    .opt("reactors", "", "reactor threads (default from config)")
    .opt("max-inflight", "", "admission budget: searches in flight before shedding")
    .opt("idle-timeout-ms", "", "close idle connections after this many ms (0 = never)")
    .opt(
        "max-docs",
        "",
        "appends open a fresh local shard once this node holds this many docs",
    )
    .opt(
        "metrics-addr",
        "",
        "also serve Prometheus text at http://<addr>/metrics plus \
         /healthz and /readyz health probes (empty = off)",
    );
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("emdpar"));
        return Ok(());
    }
    let p = spec.parse(args)?;
    let mut cfg = build_config(&p)?;
    if let Some(listen) = p.opt_str("listen") {
        if !listen.is_empty() {
            cfg.listen = listen.to_string();
        }
    }
    if !p.str("reactors").is_empty() {
        cfg.serve.reactors = p.usize("reactors")?;
    }
    if !p.str("max-inflight").is_empty() {
        cfg.serve.max_inflight = p.usize("max-inflight")?;
    }
    if !p.str("idle-timeout-ms").is_empty() {
        cfg.serve.idle_timeout_ms = p.usize("idle-timeout-ms")? as u64;
    }
    if !p.str("max-docs").is_empty() {
        let mut sp = cfg.sharded.unwrap_or_default();
        sp.max_docs_per_shard = p.usize("max-docs")?.max(1);
        cfg.sharded = Some(sp);
    }
    let shard = p.usize("shard")?;
    let of = p.usize("of")?;
    let cfg = emdpar::remote::node_config(cfg, shard, of)?;
    let listen = cfg.listen.clone();
    let maddr = p.opt_str("metrics-addr").filter(|s| !s.is_empty()).map(String::from);
    let engine = EngineBuilder::from_config(cfg).build_search()?;
    println!(
        "node shard {shard}/{of}: '{}' ({} docs) ready; listening on {listen}",
        engine.dataset().name,
        engine.num_docs()
    );
    let server = ReactorServer::bind(engine, &listen)?;
    spawn_obs(maddr.as_deref(), server.engine(), Some(server.ready_probe()))?;
    emdpar::serve::sys::arm_shutdown_signals();
    server.serve_until(emdpar::serve::sys::shutdown_flag())
}

fn cmd_trace(args: &[String]) -> EmdResult<()> {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    let spec = CommandSpec::new(
        "trace",
        "dump a running server's span ring as Chrome trace-event JSON",
    )
    .opt("op", "dump", "dump")
    .opt("addr", "127.0.0.1:7878", "server address (the line-protocol listener)")
    .opt("out", "", "write the JSON here (default: stdout)");
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("emdpar"));
        return Ok(());
    }
    let p = spec.parse(args)?;
    emdpar::emd_ensure!(
        p.str("op") == "dump",
        "unknown trace op '{}' (expected 'dump')",
        p.str("op")
    );
    let addr = p.str("addr");
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut w = stream;
    w.write_all(b"{\"op\":\"trace\"}\n")?;
    w.flush()?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let line = line.trim();
    emdpar::emd_ensure!(!line.is_empty(), "empty response from {addr}");
    // the response line IS the trace-event JSON (extra top-level keys are
    // ignored by chrome://tracing / Perfetto)
    match p.opt_str("out") {
        Some(path) if !path.is_empty() => {
            std::fs::write(path, format!("{line}\n"))?;
            eprintln!("wrote {path}");
        }
        _ => println!("{line}"),
    }
    Ok(())
}

fn cmd_telemetry(args: &[String]) -> EmdResult<()> {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    let spec = CommandSpec::new(
        "telemetry",
        "snapshot a running server's workload telemetry + audited recall",
    )
    .opt("addr", "127.0.0.1:7878", "server address (the line-protocol listener)")
    .opt("out", "", "write the JSON snapshot here (default: stdout)")
    .flag("pretty", "pretty-print the JSON");
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("emdpar"));
        return Ok(());
    }
    let p = spec.parse(args)?;
    let addr = p.str("addr");
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut w = stream;
    w.write_all(b"{\"op\":\"telemetry\"}\n")?;
    w.flush()?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let line = line.trim();
    emdpar::emd_ensure!(!line.is_empty(), "empty response from {addr}");
    let payload = if p.flag("pretty") {
        emdpar::util::json::Json::parse(line)?.to_string_pretty()
    } else {
        line.to_string()
    };
    match p.opt_str("out") {
        Some(path) if !path.is_empty() => {
            std::fs::write(path, format!("{payload}\n"))?;
            eprintln!("wrote {path}");
        }
        _ => println!("{payload}"),
    }
    Ok(())
}

fn cmd_artifacts_check(args: &[String]) -> EmdResult<()> {
    let spec = CommandSpec::new("artifacts-check", "compile artifacts; verify PJRT == native")
        .opt("dir", "artifacts", "artifact directory")
        .opt("profile", "dev", "profile to cross-check numerically");
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("emdpar"));
        return Ok(());
    }
    let p = spec.parse(args)?;
    let exec = Executor::new(Path::new(p.str("dir")))?;
    println!("PJRT platform: {}", exec.platform());
    println!("manifest: {} artifacts", exec.manifest().artifacts.len());

    // numeric cross-check on the requested profile
    let profile = p.str("profile");
    let fused = exec
        .manifest()
        .artifacts
        .values()
        .find(|a| a.profile == profile && a.entry == emdpar::runtime::Entry::Fused)
        .ok_or_else(|| EmdError::artifact(format!("no fused artifact in profile '{profile}'")))?
        .clone();
    let ds = data::generate_text(&TextConfig {
        n: 64,
        classes: 4,
        vocab: fused.v,
        dim: fused.m,
        doc_len: (fused.h / 2).max(5),
        seed: 7,
        ..Default::default()
    });
    let art = ArtifactEngine::new(&exec, &ds, profile)?;
    let k = exec.manifest().ks_for(profile).into_iter().find(|&k| k >= 2).unwrap_or(1);
    let q = ds.histogram(0);
    let got = art.distances(&q, k, true)?;
    let native = LcEngine::new(
        std::sync::Arc::new(ds.clone()),
        EngineParams { metric: Metric::L2, threads: 2, symmetric: true, ..Default::default() },
    )
    .distances(&q, Method::Act { k });
    let mut max_err = 0.0f32;
    for (g, n) in got.iter().zip(&native) {
        max_err = max_err.max((g - n).abs());
    }
    println!(
        "profile '{profile}' k={k}: max |PJRT - native| = {max_err:.2e} over {} docs",
        got.len()
    );
    emdpar::emd_ensure!(max_err < 1e-3, "artifact/native mismatch {max_err}");
    println!("artifacts-check OK");
    Ok(())
}
