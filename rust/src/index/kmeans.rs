//! Data-parallel Lloyd's k-means over document centroid vectors — the
//! coarse quantizer behind the IVF pruning index.
//!
//! k-means++ seeding draws from [`crate::util::rng::Rng`] so training is
//! deterministic from its seed; the assignment step (the `O(n·k·m)` hot
//! loop) is data-parallel over points via
//! [`crate::util::threadpool::parallel_for`] with disjoint-index writes, so
//! the result is bit-identical for every thread count.  The update step is
//! a serial `O(n·m)` accumulation, which keeps the centroid sums in one
//! deterministic order.

use crate::util::rng::Rng;
use crate::util::threadpool::{parallel_for, SyncSlice};

/// Trained quantizer: `(k, dim)` centroids plus the final assignment of
/// every input point to its nearest centroid.
#[derive(Debug, Clone, PartialEq)]
pub struct KmeansResult {
    /// Number of centroids actually trained (clamped to the point count).
    pub k: usize,
    /// Vector dimensionality.
    pub dim: usize,
    /// Row-major `(k, dim)` centroid table.
    pub centroids: Vec<f64>,
    /// Nearest-centroid id per input point (ties break to the lower id).
    pub assignments: Vec<u32>,
    /// Lloyd rounds actually run (early exit when assignments stabilize).
    pub iters_run: usize,
}

#[inline]
fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Lower `d2[i]` to the squared distance from point `i` to `center` when
/// that is smaller (the k-means++ seeding update), parallel over points.
fn min_d2_update(points: &[f64], m: usize, center: &[f64], d2: &mut [f64], threads: usize) {
    let slots = SyncSlice::new(d2);
    parallel_for(slots.len(), threads, |start, end| {
        for i in start..end {
            let d = dist_sq(&points[i * m..(i + 1) * m], center);
            // SAFETY: index i is owned by exactly this chunk.
            unsafe {
                if d < slots.get(i) {
                    slots.write(i, d);
                }
            }
        }
    });
}

/// Assign every point to its nearest centroid (ties to the lower id),
/// recording the squared distance; returns whether any assignment changed.
fn assign(
    points: &[f64],
    m: usize,
    centroids: &[f64],
    assignments: &mut [u32],
    d2: &mut [f64],
    threads: usize,
) -> bool {
    let n = assignments.len();
    let k = centroids.len() / m;
    let changed = std::sync::atomic::AtomicUsize::new(0);
    {
        let aslots = SyncSlice::new(assignments);
        let dslots = SyncSlice::new(d2);
        let changed = &changed;
        parallel_for(n, threads, |start, end| {
            for i in start..end {
                let p = &points[i * m..(i + 1) * m];
                let mut best = 0usize;
                let mut bd = f64::INFINITY;
                for c in 0..k {
                    let d = dist_sq(p, &centroids[c * m..(c + 1) * m]);
                    if d < bd {
                        bd = d;
                        best = c;
                    }
                }
                // SAFETY: index i is owned by exactly this chunk.
                unsafe {
                    if aslots.get(i) != best as u32 {
                        changed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    aslots.write(i, best as u32);
                    dslots.write(i, bd);
                }
            }
        });
    }
    changed.load(std::sync::atomic::Ordering::Relaxed) > 0
}

/// k-means++ seeding: first centroid uniform, the rest D²-weighted.
fn seed_centroids(points: &[f64], m: usize, k: usize, rng: &mut Rng, threads: usize) -> Vec<f64> {
    let n = points.len() / m;
    let mut centroids = vec![0.0f64; k * m];
    let first = rng.below(n);
    centroids[..m].copy_from_slice(&points[first * m..(first + 1) * m]);
    let mut d2 = vec![f64::INFINITY; n];
    min_d2_update(points, m, &centroids[..m], &mut d2, threads);
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total > 0.0 {
            // cumulative scan (the weights change every round, so the
            // linear pass is the whole cost anyway)
            let mut u = rng.f64() * total;
            let mut chosen = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                u -= w;
                if u <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            chosen
        } else {
            // all remaining points coincide with a chosen centroid
            rng.below(n)
        };
        centroids[c * m..(c + 1) * m].copy_from_slice(&points[pick * m..(pick + 1) * m]);
        min_d2_update(points, m, &centroids[c * m..(c + 1) * m], &mut d2, threads);
    }
    centroids
}

/// Cluster the row-major `(n, m)` matrix `points` into `k` centroids with
/// up to `iters` Lloyd rounds.  `k` is clamped to `[1, n]`.  Empty clusters
/// are reseeded deterministically to the point currently farthest from its
/// assigned centroid.
pub fn kmeans(
    points: &[f64],
    m: usize,
    k: usize,
    iters: usize,
    seed: u64,
    threads: usize,
) -> KmeansResult {
    assert!(m >= 1, "kmeans dim must be >= 1");
    assert!(!points.is_empty() && points.len() % m == 0, "kmeans point matrix shape mismatch");
    let n = points.len() / m;
    let k = k.clamp(1, n);
    let mut rng = Rng::new(seed);
    let mut centroids = seed_centroids(points, m, k, &mut rng, threads);

    let mut assignments = vec![0u32; n];
    let mut d2 = vec![0.0f64; n];
    assign(points, m, &centroids, &mut assignments, &mut d2, threads);

    let mut iters_run = 0usize;
    for _ in 0..iters.max(1) {
        iters_run += 1;
        // update: centroid = mean of its members (serial, deterministic)
        let mut sums = vec![0.0f64; k * m];
        let mut counts = vec![0usize; k];
        for (i, &a) in assignments.iter().enumerate() {
            let a = a as usize;
            counts[a] += 1;
            for (acc, &x) in sums[a * m..(a + 1) * m].iter_mut().zip(&points[i * m..(i + 1) * m])
            {
                *acc += x;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f64;
                for (slot, &s) in
                    centroids[c * m..(c + 1) * m].iter_mut().zip(&sums[c * m..(c + 1) * m])
                {
                    *slot = s * inv;
                }
            }
        }
        // empty clusters: reseed to the point farthest from its assigned
        // centroid (ties to the lowest index), each empty cluster taking a
        // distinct point
        for c in 0..k {
            if counts[c] == 0 {
                let mut best = 0usize;
                let mut bd = -1.0f64;
                for (i, &d) in d2.iter().enumerate() {
                    if d > bd {
                        bd = d;
                        best = i;
                    }
                }
                centroids[c * m..(c + 1) * m]
                    .copy_from_slice(&points[best * m..(best + 1) * m]);
                d2[best] = 0.0;
            }
        }
        let changed = assign(points, m, &centroids, &mut assignments, &mut d2, threads);
        if !changed {
            break;
        }
    }
    KmeansResult { k, dim: m, centroids, assignments, iters_run }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated 2-D blobs (spread ≪ separation, so D²-weighted
    /// seeding lands one centroid per blob for any seed in practice).
    fn blobs(seed: u64, per: usize) -> Vec<f64> {
        let centers = [(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)];
        let mut rng = Rng::new(seed);
        let mut pts = Vec::with_capacity(3 * per * 2);
        for &(cx, cy) in &centers {
            for _ in 0..per {
                pts.push(cx + rng.normal_ms(0.0, 0.05));
                pts.push(cy + rng.normal_ms(0.0, 0.05));
            }
        }
        pts
    }

    #[test]
    fn recovers_separated_blobs() {
        let per = 20;
        let pts = blobs(1, per);
        let km = kmeans(&pts, 2, 3, 20, 7, 2);
        assert_eq!(km.k, 3);
        // each blob maps to exactly one cluster, and the three differ
        let mut blob_cluster = Vec::new();
        for b in 0..3 {
            let first = km.assignments[b * per];
            assert!(
                km.assignments[b * per..(b + 1) * per].iter().all(|&a| a == first),
                "blob {b} split across clusters"
            );
            blob_cluster.push(first);
        }
        blob_cluster.sort_unstable();
        blob_cluster.dedup();
        assert_eq!(blob_cluster.len(), 3);
    }

    #[test]
    fn deterministic_and_thread_invariant() {
        let pts = blobs(2, 15);
        let a = kmeans(&pts, 2, 4, 10, 3, 1);
        let b = kmeans(&pts, 2, 4, 10, 3, 8);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.assignments, b.assignments);
        let c = kmeans(&pts, 2, 4, 10, 3, 1);
        assert_eq!(a, c);
    }

    #[test]
    fn k_clamps_to_point_count() {
        let pts = vec![0.0, 0.0, 1.0, 1.0]; // 2 points in 2-D
        let km = kmeans(&pts, 2, 10, 5, 1, 1);
        assert_eq!(km.k, 2);
        assert_eq!(km.assignments.len(), 2);
    }

    #[test]
    fn identical_points_do_not_panic() {
        let pts = vec![1.0f64; 5 * 3]; // 5 identical 3-D points
        let km = kmeans(&pts, 3, 3, 10, 1, 2);
        assert_eq!(km.assignments.len(), 5);
        assert!(km.centroids.iter().all(|c| c.is_finite()));
    }

    #[test]
    fn assignments_are_nearest_final_centroid() {
        let pts = blobs(4, 10);
        let km = kmeans(&pts, 2, 3, 8, 5, 2);
        for i in 0..30 {
            let p = &pts[i * 2..(i + 1) * 2];
            let mut best = 0usize;
            let mut bd = f64::INFINITY;
            for c in 0..km.k {
                let d = dist_sq(p, &km.centroids[c * 2..(c + 1) * 2]);
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            assert_eq!(km.assignments[i] as usize, best, "point {i}");
        }
    }
}
