//! Binary persistence for the trained IVF index (substrate: no serde).
//!
//! Format `EMDX` (little-endian), the sidecar companion of the `EMD1`
//! dataset format in [`crate::data::store`]:
//! ```text
//! magic "EMDX" | version u32 = 1
//! fingerprint u64           (dataset_fingerprint of the training data)
//! dim u64 | nlist u64 | npoints u64
//! centroids f64[nlist*dim]
//! list_ptr u64[nlist+1]
//! list_ids u32[npoints]
//! list_radius f64[nlist]
//! ```
//! [`load_for`] rejects an index whose embedded fingerprint does not match
//! the dataset it is being attached to, so a stale sidecar can never route
//! queries against data it was not trained on.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use crate::core::{EmdError, EmdResult};

use super::ivf::IvfIndex;

const MAGIC: &[u8; 4] = b"EMDX";
const VERSION: u32 = 1;

/// The conventional sidecar path for a dataset file: `ds.bin` → `ds.emdx`.
pub fn sidecar_path(dataset_path: &Path) -> PathBuf {
    dataset_path.with_extension("emdx")
}

/// Byte length of one serialized index body (fingerprint + dims header +
/// tables) given its header dims — shared by the v1 sidecar and the v2
/// shard manifest ([`crate::shard::manifest`]) so both validate
/// header-implied sizes the same way.
pub(crate) fn body_len(dim: usize, nlist: usize, npoints: usize) -> u128 {
    32u128 // fingerprint + dim + nlist + npoints
        + (nlist as u128) * (dim as u128) * 8
        + (nlist as u128 + 1) * 8
        + (npoints as u128) * 4
        + (nlist as u128) * 8
}

/// Serialize one index body (everything after the magic/version header).
pub(crate) fn write_body<W: Write>(w: &mut W, ix: &IvfIndex) -> io::Result<()> {
    let (dim, centroids, list_ptr, list_ids, list_radius, fingerprint) = ix.raw_parts();
    w.write_all(&fingerprint.to_le_bytes())?;
    w.write_all(&(dim as u64).to_le_bytes())?;
    w.write_all(&(ix.nlist() as u64).to_le_bytes())?;
    w.write_all(&(ix.num_points() as u64).to_le_bytes())?;
    for &x in centroids {
        w.write_all(&x.to_le_bytes())?;
    }
    for &p in list_ptr {
        w.write_all(&(p as u64).to_le_bytes())?;
    }
    for &u in list_ids {
        w.write_all(&u.to_le_bytes())?;
    }
    for &r in list_radius {
        w.write_all(&r.to_le_bytes())?;
    }
    Ok(())
}

/// Deserialize one index body.  `budget` is how many bytes the caller can
/// prove remain in the file: header-implied table sizes are validated
/// against it **before any allocation is sized from them**, so a corrupt
/// header (e.g. an absurd `nlist`) fails with a clean error the
/// log-and-retrain fallback can catch, never an abort.  Returns the index
/// and the bytes consumed.
pub(crate) fn read_body<R: Read>(r: &mut R, budget: u64) -> EmdResult<(IvfIndex, u64)> {
    if budget < 32 {
        return Err(EmdError::config(format!(
            "corrupt EMDX header: body needs at least 32 bytes but only {budget} remain"
        )));
    }
    let fingerprint = read_u64(r)?;
    let dim = read_u64(r)? as usize;
    let nlist = read_u64(r)? as usize;
    let npoints = read_u64(r)? as usize;
    let expected = body_len(dim, nlist, npoints);
    if expected > budget as u128 {
        return Err(EmdError::config(format!(
            "corrupt EMDX header: dim {dim} / nlist {nlist} / npoints {npoints} \
             imply {expected} bytes but only {budget} remain"
        )));
    }
    let mut centroids = Vec::with_capacity(nlist * dim);
    for _ in 0..nlist * dim {
        centroids.push(read_f64(r)?);
    }
    let mut list_ptr = Vec::with_capacity(nlist + 1);
    for _ in 0..=nlist {
        list_ptr.push(read_u64(r)? as usize);
    }
    let mut list_ids = Vec::with_capacity(npoints);
    for _ in 0..npoints {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        list_ids.push(u32::from_le_bytes(b));
    }
    let mut list_radius = Vec::with_capacity(nlist);
    for _ in 0..nlist {
        list_radius.push(read_f64(r)?);
    }
    let ix = IvfIndex::from_raw(dim, centroids, list_ptr, list_ids, list_radius, fingerprint)?;
    Ok((ix, expected as u64))
}

/// Save a trained index.
pub fn save(ix: &IvfIndex, path: &Path) -> EmdResult<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    write_body(&mut w, ix)?;
    w.flush()?;
    Ok(())
}

/// Load an index without checking what dataset it belongs to (inspection
/// use; serving paths should use [`load_for`]).
pub fn load(path: &Path) -> EmdResult<IvfIndex> {
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(
            io::Error::new(io::ErrorKind::InvalidData, "bad magic (not an EMDX file)").into()
        );
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        return Err(EmdError::config(format!(
            "unsupported EMDX version {version} (expected {VERSION}; version 2 is the \
             sharded-corpus manifest, see crate::shard)"
        )));
    }
    let budget = file_len.saturating_sub(8); // magic + version consumed
    let (ix, consumed) = read_body(&mut r, budget).map_err(|e| match e {
        EmdError::Config(m) => EmdError::config(format!("{m} (in {path:?})")),
        other => other,
    })?;
    if consumed != budget {
        return Err(EmdError::config(format!(
            "corrupt EMDX header in {path:?}: body is {consumed} bytes but the file \
             carries {budget}"
        )));
    }
    Ok(ix)
}

/// Load an index for a specific dataset, rejecting a stale sidecar whose
/// embedded fingerprint does not match `expected_fingerprint`.
pub fn load_for(path: &Path, expected_fingerprint: u64) -> EmdResult<IvfIndex> {
    let ix = load(path)?;
    if ix.fingerprint() != expected_fingerprint {
        return Err(EmdError::config(format!(
            "stale index {path:?}: fingerprint {:#018x} does not match dataset {:#018x} — \
             rebuild with `emdpar index --op build`",
            ix.fingerprint(),
            expected_fingerprint
        )));
    }
    Ok(ix)
}

fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f64<R: Read>(r: &mut R) -> io::Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::IndexParams;
    use crate::util::rng::Rng;

    fn index(seed: u64) -> IvfIndex {
        let mut rng = Rng::new(seed);
        let pts: Vec<f64> = (0..40 * 3).map(|_| rng.normal()).collect();
        IvfIndex::train(
            &pts,
            3,
            &IndexParams { nlist: 5, nprobe: 2, train_iters: 6, seed: 3, min_points_per_list: 1 },
            2,
            0xfeed,
        )
        .unwrap()
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("emdpar_index_persist_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let ix = index(1);
        let path = tmp("roundtrip.emdx");
        save(&ix, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, ix);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stale_fingerprint_rejected() {
        let ix = index(2);
        let path = tmp("stale.emdx");
        save(&ix, &path).unwrap();
        assert!(load_for(&path, 0xfeed).is_ok());
        let err = load_for(&path, 0xdead).unwrap_err();
        assert!(err.to_string().contains("stale index"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("junk.emdx");
        std::fs::write(&path, b"NOPEnopenope").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_header_rejected_before_allocation() {
        // valid magic/version but an absurd nlist: the length check must
        // reject it cleanly (no multi-TB Vec::with_capacity)
        let path = tmp("corrupt.emdx");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"EMDX");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes()); // fingerprint
        bytes.extend_from_slice(&8u64.to_le_bytes()); // dim
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes()); // nlist: bogus
        bytes.extend_from_slice(&10u64.to_le_bytes()); // npoints
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("corrupt EMDX header"), "{err}");
        // a truncated but otherwise sane file is also a clean error
        let ix = index(3);
        save(&ix, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sidecar_path_swaps_extension() {
        assert_eq!(sidecar_path(Path::new("data/ds.bin")), PathBuf::from("data/ds.emdx"));
        assert_eq!(sidecar_path(Path::new("plain")), PathBuf::from("plain.emdx"));
    }
}
