//! The trained IVF coarse quantizer: a centroid table over document WCD
//! centroids plus CSR-style inverted lists mapping each k-means cell to the
//! database rows it contains.
//!
//! The index answers `probe(query_centroid, nprobe)` with the nearest
//! `nprobe` lists (ties to the lower list id) and
//! [`IvfIndex::candidates`] with the merged, ascending row-id union of a
//! probed list set — the shortlist the pruned search layer scores through
//! the LC engines.  A content fingerprint of the training dataset travels
//! with the index so a persisted (`EMDX`) index can be rejected when the
//! dataset underneath it changed.

use crate::config::IndexParams;
use crate::core::compress::{f16_to_f32, f32_to_f16};
use crate::core::{Dataset, EmdResult};
use crate::emd_ensure;

use super::kmeans::kmeans;

/// A trained IVF index over one dataset's WCD centroid matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct IvfIndex {
    dim: usize,
    /// Row-major `(nlist, dim)` list centroid table.
    centroids: Vec<f64>,
    /// CSR offsets into `list_ids`, length `nlist + 1`.
    list_ptr: Vec<usize>,
    /// Database row ids, ascending within each list; length = dataset size.
    list_ids: Vec<u32>,
    /// Per-list stats: max member-centroid-to-list-centroid distance.
    list_radius: Vec<f64>,
    /// Fingerprint of the dataset the index was trained on.
    fingerprint: u64,
    /// Optional f16 copy of the centroid table (compressed stage-1
    /// residency).  Never present at construction — populated only by
    /// [`IvfIndex::enable_compressed_centroids`], so the persisted raw-parts
    /// form stays unchanged and a reloaded index equals the original.
    centroids_f16: Option<Vec<u16>>,
}

/// The list count training actually uses: `nlist` capped so the average
/// list keeps at least `min_points_per_list` members (and never exceeds the
/// point count).
pub fn effective_nlist(params: &IndexParams, n: usize) -> usize {
    let cap = n / params.min_points_per_list.max(1);
    params.nlist.min(cap.max(1)).min(n.max(1)).max(1)
}

impl IvfIndex {
    /// Train on a row-major `(n, m)` centroid matrix (the output of
    /// [`crate::approx::centroids_batch`], owned by the LC engine as its
    /// WCD table).  `fingerprint` should come from [`dataset_fingerprint`]
    /// of the dataset those centroids describe.
    pub fn train(
        points: &[f64],
        m: usize,
        params: &IndexParams,
        threads: usize,
        fingerprint: u64,
    ) -> EmdResult<IvfIndex> {
        emd_ensure!(m >= 1, config, "index dim must be >= 1");
        emd_ensure!(
            !points.is_empty() && points.len() % m == 0,
            config,
            "centroid matrix shape mismatch (len {} vs dim {m})",
            points.len()
        );
        let n = points.len() / m;
        let nlist = effective_nlist(params, n);
        let km = kmeans(points, m, nlist, params.train_iters.max(1), params.seed, threads);
        let nlist = km.k;

        // CSR inverted lists; iterating rows in order keeps each list's ids
        // ascending (the candidate-merge and tie-break contract).
        let mut counts = vec![0usize; nlist];
        for &a in &km.assignments {
            counts[a as usize] += 1;
        }
        let mut list_ptr = vec![0usize; nlist + 1];
        for c in 0..nlist {
            list_ptr[c + 1] = list_ptr[c] + counts[c];
        }
        let mut cursor = list_ptr.clone();
        let mut list_ids = vec![0u32; n];
        for (u, &a) in km.assignments.iter().enumerate() {
            list_ids[cursor[a as usize]] = u as u32;
            cursor[a as usize] += 1;
        }
        let mut list_radius = vec![0.0f64; nlist];
        for (u, &a) in km.assignments.iter().enumerate() {
            let a = a as usize;
            let d = euclid(&points[u * m..(u + 1) * m], &km.centroids[a * m..(a + 1) * m]);
            if d > list_radius[a] {
                list_radius[a] = d;
            }
        }
        Ok(IvfIndex {
            dim: m,
            centroids: km.centroids,
            list_ptr,
            list_ids,
            list_radius,
            fingerprint,
            centroids_f16: None,
        })
    }

    /// Reassemble from raw parts (the persistence loader); validates the
    /// CSR structure and that every database row appears exactly once.
    pub fn from_raw(
        dim: usize,
        centroids: Vec<f64>,
        list_ptr: Vec<usize>,
        list_ids: Vec<u32>,
        list_radius: Vec<f64>,
        fingerprint: u64,
    ) -> EmdResult<IvfIndex> {
        emd_ensure!(dim >= 1, config, "index dim must be >= 1");
        emd_ensure!(
            !list_ptr.is_empty() && list_ptr[0] == 0,
            config,
            "index list_ptr must start at 0"
        );
        let nlist = list_ptr.len() - 1;
        emd_ensure!(nlist >= 1, config, "index needs at least one list");
        emd_ensure!(
            centroids.len() == nlist * dim,
            config,
            "index centroid table shape mismatch"
        );
        emd_ensure!(list_radius.len() == nlist, config, "index list stats length mismatch");
        emd_ensure!(
            list_ptr.windows(2).all(|w| w[0] <= w[1]),
            config,
            "index list_ptr must be monotone"
        );
        emd_ensure!(
            *list_ptr.last().unwrap() == list_ids.len(),
            config,
            "index list_ptr/list_ids mismatch"
        );
        let n = list_ids.len();
        let mut seen = vec![false; n];
        for &u in &list_ids {
            emd_ensure!((u as usize) < n, config, "index row id {u} out of range");
            emd_ensure!(!seen[u as usize], config, "index row id {u} appears twice");
            seen[u as usize] = true;
        }
        Ok(IvfIndex {
            dim,
            centroids,
            list_ptr,
            list_ids,
            list_radius,
            fingerprint,
            centroids_f16: None,
        })
    }

    pub fn nlist(&self) -> usize {
        self.list_ptr.len() - 1
    }

    /// Number of indexed database rows.
    pub fn num_points(&self) -> usize {
        self.list_ids.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// The centroid of list `c`.
    pub fn centroid(&self, c: usize) -> &[f64] {
        &self.centroids[c * self.dim..(c + 1) * self.dim]
    }

    /// The (ascending) database row ids of list `c`.
    pub fn list(&self, c: usize) -> &[u32] {
        &self.list_ids[self.list_ptr[c]..self.list_ptr[c + 1]]
    }

    /// Max member-to-centroid distance of list `c`.
    pub fn list_radius(&self, c: usize) -> f64 {
        self.list_radius[c]
    }

    /// Member count per list (shape reporting).
    pub fn list_sizes(&self) -> Vec<usize> {
        (0..self.nlist()).map(|c| self.list_ptr[c + 1] - self.list_ptr[c]).collect()
    }

    /// The nearest list to a centroid vector (the training assignment rule:
    /// ties to the lower list id).
    pub fn assign(&self, centroid: &[f64]) -> usize {
        self.probe(centroid, 1)[0]
    }

    /// The `nprobe` nearest lists to `query_centroid`, nearest first (ties
    /// to the lower list id).  `nprobe` is clamped to `[1, nlist]`.
    pub fn probe(&self, query_centroid: &[f64], nprobe: usize) -> Vec<usize> {
        assert_eq!(query_centroid.len(), self.dim, "query centroid dim mismatch");
        let nlist = self.nlist();
        let nprobe = nprobe.clamp(1, nlist);
        let mut order: Vec<(f64, usize)> = (0..nlist)
            .map(|c| (euclid(query_centroid, self.centroid(c)), c))
            .collect();
        order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        order.truncate(nprobe);
        order.into_iter().map(|(_, c)| c).collect()
    }

    /// Build the f16 copy of the centroid table (compressed stage-1
    /// residency).  Each f64 centroid coordinate is narrowed through f32 to
    /// IEEE binary16 with round-to-nearest-even.  Idempotent; the exact
    /// table is untouched, so assignment, appends and persistence are
    /// unaffected.  [`IvfIndex::append_assigned`] never modifies the
    /// centroid table, so an enabled tier stays valid across appends.
    pub fn enable_compressed_centroids(&mut self) {
        if self.centroids_f16.is_none() {
            self.centroids_f16 =
                Some(self.centroids.iter().map(|&x| f32_to_f16(x as f32)).collect());
        }
    }

    /// Whether the f16 centroid tier is resident.
    pub fn compressed_centroids_active(&self) -> bool {
        self.centroids_f16.is_some()
    }

    /// [`IvfIndex::probe`] against the f16 centroid tier: each centroid is
    /// decoded f16→f32→f64 and ranked by the identical
    /// `(distance, list id)` ordering.  Falls back to the exact probe when
    /// the tier has not been enabled.  Probe order may differ from the
    /// exact probe only when quantization reorders near-tied centroids —
    /// the caller (the query planner) compensates with an exact rerank of
    /// the scored shortlist.
    pub fn probe_compressed(&self, query_centroid: &[f64], nprobe: usize) -> Vec<usize> {
        let Some(cf) = &self.centroids_f16 else {
            return self.probe(query_centroid, nprobe);
        };
        assert_eq!(query_centroid.len(), self.dim, "query centroid dim mismatch");
        let nlist = self.nlist();
        let nprobe = nprobe.clamp(1, nlist);
        let mut dec = vec![0.0f64; self.dim];
        let mut order: Vec<(f64, usize)> = (0..nlist)
            .map(|c| {
                for (d, &h) in dec.iter_mut().zip(&cf[c * self.dim..(c + 1) * self.dim]) {
                    *d = f16_to_f32(h) as f64;
                }
                (euclid(query_centroid, &dec), c)
            })
            .collect();
        order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        order.truncate(nprobe);
        order.into_iter().map(|(_, c)| c).collect()
    }

    /// Merged candidate row ids of a probed list set, ascending.  Lists are
    /// disjoint, so this is a plain sorted merge with no duplicates.
    pub fn candidates(&self, lists: &[usize]) -> Vec<u32> {
        let total: usize = lists.iter().map(|&c| self.list(c).len()).sum();
        let mut out = Vec::with_capacity(total);
        for &c in lists {
            out.extend_from_slice(self.list(c));
        }
        out.sort_unstable();
        out
    }

    /// Append one new point to its nearest list **without retraining** —
    /// the trained-once / assign-incrementally path the live-corpus append
    /// route uses.  The new point's id is the current [`IvfIndex::num_points`]
    /// (the largest id so far), so every list's ascending-id invariant is
    /// preserved; the receiving list's radius grows to cover the new member
    /// when needed.  Returns the list the point joined.
    ///
    /// The embedded dataset fingerprint is *not* updated here — after an
    /// append batch, re-stamp with [`IvfIndex::set_fingerprint`] so the
    /// index stays tied to the data it now covers.
    pub fn append_assigned(&mut self, centroid: &[f64]) -> usize {
        assert_eq!(centroid.len(), self.dim, "appended centroid dim mismatch");
        let c = self.assign(centroid);
        let new_id = self.list_ids.len() as u32;
        // the new id is the maximum, so inserting at the end of list c's
        // segment keeps that list ascending
        let pos = self.list_ptr[c + 1];
        self.list_ids.insert(pos, new_id);
        for p in &mut self.list_ptr[c + 1..] {
            *p += 1;
        }
        let d = euclid(centroid, self.centroid(c));
        if d > self.list_radius[c] {
            self.list_radius[c] = d;
        }
        c
    }

    /// Re-stamp the dataset fingerprint (after an append batch mutated the
    /// data this index covers).
    pub fn set_fingerprint(&mut self, fingerprint: u64) {
        self.fingerprint = fingerprint;
    }

    /// Destructure into raw parts (the persistence writer's view).
    pub fn raw_parts(&self) -> (usize, &[f64], &[usize], &[u32], &[f64], u64) {
        (
            self.dim,
            &self.centroids,
            &self.list_ptr,
            &self.list_ids,
            &self.list_radius,
            self.fingerprint,
        )
    }
}

#[inline]
fn euclid(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s.sqrt()
}

/// FNV-1a content fingerprint of a dataset: embeddings, labels and the CSR
/// histogram matrix all contribute, so any change to the data a persisted
/// index was trained on invalidates it.
pub fn dataset_fingerprint(ds: &Dataset) -> u64 {
    let mut h = Fnv::new();
    h.write_u64(ds.len() as u64);
    h.write_u64(ds.embeddings.num_vectors() as u64);
    h.write_u64(ds.embeddings.dim() as u64);
    for &x in ds.embeddings.as_slice() {
        h.write_u32(x.to_bits());
    }
    for &l in &ds.labels {
        h.write_u32(l as u32);
    }
    for u in 0..ds.len() {
        let (idx, w) = ds.matrix.row(u);
        h.write_u64(idx.len() as u64);
        for &i in idx {
            h.write_u32(i);
        }
        for &x in w {
            h.write_u32(x.to_bits());
        }
    }
    h.finish()
}

/// Minimal FNV-1a 64-bit hasher (substrate: no external hash crates).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }

    #[inline]
    fn write_u8(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x100000001b3);
    }

    #[inline]
    fn write_u32(&mut self, x: u32) {
        for b in x.to_le_bytes() {
            self.write_u8(b);
        }
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.write_u8(b);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_text, TextConfig};
    use crate::util::rng::Rng;

    fn params(nlist: usize) -> IndexParams {
        IndexParams { nlist, nprobe: 2, train_iters: 8, seed: 11, min_points_per_list: 1 }
    }

    fn grid_points(n: usize, m: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n * m).map(|_| rng.normal()).collect()
    }

    #[test]
    fn lists_partition_the_database() {
        let pts = grid_points(50, 3, 1);
        let ix = IvfIndex::train(&pts, 3, &params(6), 2, 99).unwrap();
        assert_eq!(ix.num_points(), 50);
        assert_eq!(ix.fingerprint(), 99);
        let all = ix.candidates(&(0..ix.nlist()).collect::<Vec<_>>());
        assert_eq!(all, (0..50u32).collect::<Vec<_>>());
        for c in 0..ix.nlist() {
            assert!(ix.list(c).windows(2).all(|w| w[0] < w[1]), "list {c} not ascending");
            assert!(ix.list_radius(c) >= 0.0);
        }
    }

    #[test]
    fn probe_returns_nearest_lists_first() {
        let pts = grid_points(60, 2, 2);
        let ix = IvfIndex::train(&pts, 2, &params(5), 1, 0).unwrap();
        let q = &pts[0..2];
        let order = ix.probe(q, ix.nlist());
        assert_eq!(order.len(), ix.nlist());
        let mut prev = -1.0f64;
        for &c in &order {
            let d = {
                let cc = ix.centroid(c);
                ((q[0] - cc[0]).powi(2) + (q[1] - cc[1]).powi(2)).sqrt()
            };
            assert!(d >= prev, "probe order not ascending");
            prev = d;
        }
        // the nearest list is what assign() picks
        assert_eq!(ix.assign(q), order[0]);
        // point 0's own list must be its nearest list
        let own = (0..ix.nlist()).find(|&c| ix.list(c).contains(&0)).unwrap();
        assert_eq!(own, order[0]);
    }

    #[test]
    fn min_points_per_list_caps_nlist() {
        let pts = grid_points(40, 2, 3);
        let p = IndexParams { nlist: 1000, min_points_per_list: 10, ..params(1000) };
        assert_eq!(effective_nlist(&p, 40), 4);
        let ix = IvfIndex::train(&pts, 2, &p, 1, 0).unwrap();
        assert!(ix.nlist() <= 4);
    }

    #[test]
    fn append_assigned_preserves_invariants() {
        let pts = grid_points(30, 2, 7);
        let mut ix = IvfIndex::train(&pts, 2, &params(4), 2, 42).unwrap();
        let nlist = ix.nlist();
        // three appended points: each joins its nearest list with the next
        // free id, lists stay ascending, and the partition stays complete
        for (j, q) in [[0.1f64, -0.2], [2.0, 2.0], [-1.5, 0.4]].iter().enumerate() {
            let expect_list = ix.assign(q);
            let got = ix.append_assigned(q);
            assert_eq!(got, expect_list);
            assert_eq!(ix.num_points(), 30 + j + 1);
            assert!(ix.list(got).contains(&((30 + j) as u32)));
            let member = euclid(q, ix.centroid(got));
            assert!(ix.list_radius(got) >= member - 1e-12);
        }
        for c in 0..nlist {
            assert!(ix.list(c).windows(2).all(|w| w[0] < w[1]), "list {c} not ascending");
        }
        let all = ix.candidates(&(0..nlist).collect::<Vec<_>>());
        assert_eq!(all, (0..33u32).collect::<Vec<_>>());
        // the mutated index still validates as a whole
        let (dim, c, p, ids, r, fp) = ix.raw_parts();
        IvfIndex::from_raw(dim, c.to_vec(), p.to_vec(), ids.to_vec(), r.to_vec(), fp).unwrap();
        // fingerprint re-stamping
        ix.set_fingerprint(0xbeef);
        assert_eq!(ix.fingerprint(), 0xbeef);
    }

    #[test]
    fn from_raw_validates() {
        let pts = grid_points(20, 2, 4);
        let ix = IvfIndex::train(&pts, 2, &params(3), 1, 5).unwrap();
        let (dim, c, p, ids, r, fp) = ix.raw_parts();
        let ok = IvfIndex::from_raw(dim, c.to_vec(), p.to_vec(), ids.to_vec(), r.to_vec(), fp)
            .unwrap();
        assert_eq!(ok, ix);
        // duplicated row id is rejected
        let mut bad = ids.to_vec();
        bad[0] = bad[1];
        assert!(IvfIndex::from_raw(dim, c.to_vec(), p.to_vec(), bad, r.to_vec(), fp).is_err());
        // truncated centroid table is rejected
        assert!(IvfIndex::from_raw(
            dim,
            c[..c.len() - 1].to_vec(),
            p.to_vec(),
            ids.to_vec(),
            r.to_vec(),
            fp
        )
        .is_err());
    }

    #[test]
    fn compressed_centroid_probe_matches_exact_probe() {
        let pts = grid_points(60, 3, 9);
        let mut ix = IvfIndex::train(&pts, 3, &params(6), 2, 1).unwrap();
        let q = &pts[6..9];
        // without the tier, probe_compressed IS the exact probe
        assert!(!ix.compressed_centroids_active());
        assert_eq!(ix.probe_compressed(q, 3), ix.probe(q, 3));
        ix.enable_compressed_centroids();
        assert!(ix.compressed_centroids_active());
        // idempotent
        ix.enable_compressed_centroids();
        // a full probe covers every list regardless of quantization …
        let exact = ix.probe(q, ix.nlist());
        let tiered = ix.probe_compressed(q, ix.nlist());
        let mut a = exact.clone();
        let mut b = tiered.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "full probe must cover every list");
        // … and the tiered order equals a from-scratch reference over the
        // decoded f16 table (same euclid + (distance, id) tie-break)
        let mut want: Vec<(f64, usize)> = (0..ix.nlist())
            .map(|c| {
                let d: f64 = ix
                    .centroid(c)
                    .iter()
                    .zip(q)
                    .map(|(&x, &y)| {
                        let dx = f16_to_f32(f32_to_f16(x as f32)) as f64 - y;
                        dx * dx
                    })
                    .sum::<f64>()
                    .sqrt();
                (d, c)
            })
            .collect();
        want.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
        let want: Vec<usize> = want.into_iter().map(|(_, c)| c).collect();
        assert_eq!(tiered, want);
        // the tier rides outside the persisted raw-parts form
        let (dim, c, p, ids, r, fp) = ix.raw_parts();
        let reloaded =
            IvfIndex::from_raw(dim, c.to_vec(), p.to_vec(), ids.to_vec(), r.to_vec(), fp)
                .unwrap();
        assert!(!reloaded.compressed_centroids_active());
    }

    #[test]
    fn fingerprint_tracks_content() {
        let a = generate_text(&TextConfig {
            n: 20,
            classes: 2,
            vocab: 80,
            dim: 8,
            doc_len: 15,
            seed: 1,
            ..Default::default()
        });
        let b = generate_text(&TextConfig {
            n: 20,
            classes: 2,
            vocab: 80,
            dim: 8,
            doc_len: 15,
            seed: 2,
            ..Default::default()
        });
        assert_eq!(dataset_fingerprint(&a), dataset_fingerprint(&a));
        assert_ne!(dataset_fingerprint(&a), dataset_fingerprint(&b));
    }
}
