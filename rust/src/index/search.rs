//! Pruned top-ℓ search: probe the IVF index, score only the shortlist.
//!
//! The scoring half rides the existing engine machinery —
//! [`crate::lc::LcEngine::distances_batch_subset`] gathers the candidate
//! rows into a sub-CSR matrix and runs the same batched Phase-1/Phase-2
//! pipeline as a full sweep, so every candidate's distance is bit-identical
//! to the value exhaustive search would have produced.  With
//! `nprobe = nlist` the candidate set is the whole database and the pruned
//! result equals exhaustive search exactly; smaller `nprobe` trades recall
//! for a sublinear number of scored candidates.
//!
//! For a multi-query batch the candidate sets are merged into one sorted
//! union, scored in a single engine dispatch (one Phase-1 block pipeline,
//! shared sub-CSR), and each query then ranks only its own candidates — so
//! batched pruned search returns exactly what per-query pruned search
//! returns.

use std::time::{Duration, Instant};

use crate::core::{EmdResult, Histogram, Method};
use crate::coordinator::TopL;
use crate::emd_ensure;
use crate::lc::LcEngine;

use super::ivf::IvfIndex;

/// One pruned query's outcome with pruning work accounting.
#[derive(Debug, Clone)]
pub struct PrunedSearch {
    /// (distance, database id) under `method`, best first — distances are
    /// bit-identical to the exhaustive values for the same pairs.
    pub hits: Vec<(f32, usize)>,
    /// Inverted lists visited for this query.
    pub lists_probed: usize,
    /// Database rows actually scored (this query's candidate-set size).
    pub candidates: usize,
}

/// Pruned top-ℓ for one query.
pub fn pruned_search(
    engine: &LcEngine,
    index: &IvfIndex,
    query: &Histogram,
    method: Method,
    l: usize,
    nprobe: usize,
) -> EmdResult<PrunedSearch> {
    let mut out =
        pruned_search_batch(engine, index, std::slice::from_ref(query), method, l, nprobe)?;
    Ok(out.pop().expect("one query in, one result out"))
}

/// Validate the (engine, index) pairing and probe one query: WCD centroid
/// → `nprobe` nearest lists → merged ascending candidate row ids.  The one
/// probe-path entry point, shared by pruned search and the pruned cascade
/// ([`crate::coordinator::cascade_search_pruned`]) so validation and probe
/// semantics cannot diverge.
pub fn probe_candidates(
    engine: &LcEngine,
    index: &IvfIndex,
    query: &Histogram,
    nprobe: usize,
) -> EmdResult<Vec<u32>> {
    probe_candidates_tiered(engine, index, query, nprobe, false)
}

/// [`probe_candidates`] with a residency-tier switch: when `compressed` is
/// true and the index has an f16 centroid tier, list selection runs against
/// the compressed table ([`IvfIndex::probe_compressed`]).  Candidate-set
/// semantics are otherwise identical, and at `nprobe = nlist` both tiers
/// return the whole database.
pub fn probe_candidates_tiered(
    engine: &LcEngine,
    index: &IvfIndex,
    query: &Histogram,
    nprobe: usize,
    compressed: bool,
) -> EmdResult<Vec<u32>> {
    emd_ensure!(
        index.num_points() == engine.dataset().len(),
        config,
        "index covers {} rows but the dataset has {}",
        index.num_points(),
        engine.dataset().len()
    );
    emd_ensure!(
        index.dim() == engine.dataset().embeddings.dim(),
        config,
        "index centroid dim {} does not match embedding dim {}",
        index.dim(),
        engine.dataset().embeddings.dim()
    );
    emd_ensure!(!query.is_empty(), config, "empty query histogram");
    let qc = crate::approx::centroid(&engine.dataset().embeddings, query);
    let nprobe = nprobe.clamp(1, index.nlist());
    let lists =
        if compressed { index.probe_compressed(&qc, nprobe) } else { index.probe(&qc, nprobe) };
    Ok(index.candidates(&lists))
}

/// Pruned top-ℓ for a batch of queries: one probe per query, one engine
/// dispatch over the batch's candidate union.
pub fn pruned_search_batch(
    engine: &LcEngine,
    index: &IvfIndex,
    queries: &[Histogram],
    method: Method,
    l: usize,
    nprobe: usize,
) -> EmdResult<Vec<PrunedSearch>> {
    pruned_search_batch_tiered(engine, index, queries, method, l, nprobe, false)
}

/// [`pruned_search_batch`] with a residency-tier switch.  With
/// `compressed = true` the probe uses the index's f16 centroid tier (when
/// enabled) and candidate scoring runs through the engine's compressed
/// stage-1 path ([`LcEngine::distances_batch_subset_tiered`]) — distances
/// are then f16-quantized stage-1 scores, NOT the exact values, and the
/// caller (the query planner's `ExactRerank` stage) must rescore the
/// surviving shortlist exactly.  With `compressed = false` this is exactly
/// the historical pruned search.
pub fn pruned_search_batch_tiered(
    engine: &LcEngine,
    index: &IvfIndex,
    queries: &[Histogram],
    method: Method,
    l: usize,
    nprobe: usize,
    compressed: bool,
) -> EmdResult<Vec<PrunedSearch>> {
    pruned_search_batch_tiered_timed(engine, index, queries, method, l, nprobe, compressed)
        .map(|(results, _)| results)
}

/// Probe/score wall-time split of one pruned batch dispatch — the query
/// planner's `Prune` and `Score` stage timings.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrunedTiming {
    /// IVF list selection plus candidate-union assembly.
    pub probe: Duration,
    /// Candidate scoring through the batched subset pipeline, including
    /// the per-query top-ℓ ranking.
    pub score: Duration,
}

/// [`pruned_search_batch_tiered`] returning the probe/score wall-time
/// split alongside the results (identical results, zero extra work beyond
/// two `Instant` reads).
pub fn pruned_search_batch_tiered_timed(
    engine: &LcEngine,
    index: &IvfIndex,
    queries: &[Histogram],
    method: Method,
    l: usize,
    nprobe: usize,
    compressed: bool,
) -> EmdResult<(Vec<PrunedSearch>, PrunedTiming)> {
    if queries.is_empty() {
        return Ok((Vec::new(), PrunedTiming::default()));
    }
    let t0 = Instant::now();
    let nprobe = nprobe.clamp(1, index.nlist());
    let mut per_query: Vec<Vec<u32>> = Vec::with_capacity(queries.len());
    for q in queries {
        per_query.push(probe_candidates_tiered(engine, index, q, nprobe, compressed)?);
    }

    // candidate union across the batch (lists are disjoint per query but
    // overlap across queries)
    let union: Vec<u32> = if queries.len() == 1 {
        per_query[0].clone()
    } else {
        let mut u: Vec<u32> = per_query.iter().flat_map(|c| c.iter().copied()).collect();
        u.sort_unstable();
        u.dedup();
        u
    };
    let probe_time = t0.elapsed();

    // one engine dispatch: (queries, union) distance block through the
    // batched Phase-1 pipeline
    let flat = engine.distances_batch_subset_tiered(queries, method, &union, compressed);
    let cols = union.len();

    let results = queries
        .iter()
        .enumerate()
        .map(|(qi, _)| {
            let row = &flat[qi * cols..(qi + 1) * cols];
            let mut top = TopL::new(l.max(1));
            for &id in &per_query[qi] {
                let pos = union.binary_search(&id).expect("candidate present in union");
                top.push(row[pos], id as usize);
            }
            PrunedSearch {
                hits: top.into_sorted(),
                lists_probed: nprobe,
                candidates: per_query[qi].len(),
            }
        })
        .collect();
    let score_time = t0.elapsed().saturating_sub(probe_time);
    Ok((results, PrunedTiming { probe: probe_time, score: score_time }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::IndexParams;
    use crate::data::{generate_text, TextConfig};
    use crate::index::dataset_fingerprint;
    use crate::lc::EngineParams;
    use std::sync::Arc;

    fn setup(nlist: usize) -> (Arc<crate::core::Dataset>, LcEngine, IvfIndex) {
        let ds = Arc::new(generate_text(&TextConfig {
            n: 80,
            classes: 4,
            vocab: 300,
            dim: 12,
            doc_len: 30,
            seed: 21,
            ..Default::default()
        }));
        let eng = LcEngine::new(Arc::clone(&ds), EngineParams { threads: 2, ..Default::default() });
        let fp = dataset_fingerprint(&ds);
        let ix = IvfIndex::train(
            eng.wcd_centroids(),
            ds.embeddings.dim(),
            &IndexParams {
                nlist,
                nprobe: 2,
                train_iters: 8,
                seed: 5,
                min_points_per_list: 1,
            },
            2,
            fp,
        )
        .unwrap();
        (ds, eng, ix)
    }

    #[test]
    fn full_probe_equals_exhaustive_topl() {
        let (ds, eng, ix) = setup(6);
        let q = ds.histogram(3);
        for method in [Method::Rwmd, Method::Act { k: 2 }, Method::Wcd] {
            let pruned = pruned_search(&eng, &ix, &q, method, 7, ix.nlist()).unwrap();
            let row = eng.distances(&q, method);
            let mut want = TopL::new(7);
            want.push_slice(&row, 0);
            assert_eq!(pruned.hits, want.into_sorted(), "{method}");
            assert_eq!(pruned.candidates, ds.len());
        }
    }

    #[test]
    fn batch_equals_single_query_pruned() {
        let (ds, eng, ix) = setup(8);
        let queries: Vec<Histogram> =
            [0usize, 13, 40, 41].iter().map(|&u| ds.histogram(u)).collect();
        for nprobe in [1usize, 2, 4] {
            let batch =
                pruned_search_batch(&eng, &ix, &queries, Method::Rwmd, 5, nprobe).unwrap();
            for (q, got) in queries.iter().zip(&batch) {
                let single = pruned_search(&eng, &ix, q, Method::Rwmd, 5, nprobe).unwrap();
                assert_eq!(got.hits, single.hits, "nprobe {nprobe}");
                assert_eq!(got.candidates, single.candidates);
            }
        }
    }

    #[test]
    fn pruning_reduces_scored_candidates() {
        let (ds, eng, ix) = setup(8);
        let q = ds.histogram(0);
        let res = pruned_search(&eng, &ix, &q, Method::Rwmd, 5, 2).unwrap();
        assert!(res.candidates < ds.len(), "nprobe 2 of 8 lists must prune");
        assert_eq!(res.lists_probed, 2);
        // a database query always finds itself: its own list is probed first
        assert_eq!(res.hits[0].1, 0);
        assert!(res.hits[0].0.abs() < 1e-5);
    }

    #[test]
    fn compressed_tier_full_probe_matches_tiered_full_sweep() {
        use crate::core::CompressedKind;
        let ds = Arc::new(generate_text(&TextConfig {
            n: 60,
            classes: 3,
            vocab: 250,
            dim: 12,
            doc_len: 25,
            seed: 33,
            ..Default::default()
        }));
        let eng = LcEngine::new(
            Arc::clone(&ds),
            EngineParams { threads: 2, compressed: CompressedKind::F16, ..Default::default() },
        );
        assert!(eng.compressed_active());
        let fp = dataset_fingerprint(&ds);
        let mut ix = IvfIndex::train(
            eng.wcd_centroids(),
            ds.embeddings.dim(),
            &IndexParams {
                nlist: 5,
                nprobe: 2,
                train_iters: 8,
                seed: 3,
                min_points_per_list: 1,
            },
            2,
            fp,
        )
        .unwrap();
        ix.enable_compressed_centroids();
        let queries: Vec<Histogram> = [2usize, 17].iter().map(|&u| ds.histogram(u)).collect();
        let batch = pruned_search_batch_tiered(
            &eng,
            &ix,
            &queries,
            Method::Rwmd,
            6,
            ix.nlist(),
            true,
        )
        .unwrap();
        // at full probe the compressed pruned path scores the whole
        // database through the same tiered sweep the engine exposes
        // directly, so the top-ℓ must agree bit-for-bit
        let flat = eng.distances_batch_tiered(&queries, Method::Rwmd, true);
        let n = ds.len();
        for (qi, got) in batch.iter().enumerate() {
            assert_eq!(got.candidates, n);
            let mut want = TopL::new(6);
            want.push_slice(&flat[qi * n..(qi + 1) * n], 0);
            assert_eq!(got.hits, want.into_sorted());
        }
    }

    #[test]
    fn mismatched_index_is_rejected() {
        let (_, eng, _) = setup(4);
        let other = generate_text(&TextConfig {
            n: 30,
            classes: 2,
            vocab: 300,
            dim: 12,
            doc_len: 20,
            seed: 9,
            ..Default::default()
        });
        let other_eng =
            LcEngine::new(Arc::new(other), EngineParams { threads: 1, ..Default::default() });
        let ix = IvfIndex::train(
            other_eng.wcd_centroids(),
            12,
            &IndexParams { nlist: 4, nprobe: 1, train_iters: 4, seed: 1, min_points_per_list: 1 },
            1,
            0,
        )
        .unwrap();
        let q = eng.dataset().histogram(0);
        assert!(pruned_search(&eng, &ix, &q, Method::Rwmd, 3, 1).is_err());
    }
}
