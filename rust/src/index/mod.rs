//! IVF-style centroid pruning index: sublinear candidate selection in
//! front of the LC engines.
//!
//! Every serving path used to score all `n` database rows per query; this
//! subsystem puts a coarse quantizer in front of Phase 2 so only a
//! candidate shortlist is scored.  The geometry is the WCD centroid of each
//! document (the `(n, m)` matrix [`crate::approx::centroids_batch`] already
//! computes for the engine's WCD path): documents whose centroids are close
//! are the ones cheap bounds would keep anyway, so clustering that space
//! yields a high-recall shortlist at a fraction of the scoring work — the
//! nearest-neighbor-search framing of EMD approximation (arXiv 2401.07378)
//! and the data-dependent clustering bound (arXiv 2002.12354) applied to
//! this codebase's engines.
//!
//! Layout:
//! * [`kmeans`] — data-parallel Lloyd's k-means with k-means++ seeding
//!   (deterministic per seed, thread-count invariant).
//! * [`ivf`] — the trained [`IvfIndex`]: centroid table + CSR inverted
//!   lists + per-list stats, `train`/`assign`/`probe`, and the dataset
//!   fingerprint that ties an index to its data.
//! * [`search`] — pruned top-ℓ through
//!   [`crate::lc::LcEngine::distances_batch_subset`] (bit-identical
//!   candidate distances; `nprobe = nlist` reproduces exhaustive search
//!   exactly).
//! * [`persist`] — the `EMDX` sidecar format with stale-index rejection.
//!
//! The coordinator ([`crate::coordinator::SearchEngine`]) owns an optional
//! trained index and routes `search`/`search_batch` through it; the
//! cascade composes via
//! [`crate::coordinator::cascade::cascade_search_pruned`].

pub mod ivf;
pub mod kmeans;
pub mod persist;
pub mod search;

pub use ivf::{dataset_fingerprint, effective_nlist, IvfIndex};
pub use kmeans::{kmeans, KmeansResult};
pub use persist::{
    load as load_index, load_for as load_index_for, save as save_index, sidecar_path,
};
pub use search::{
    probe_candidates, probe_candidates_tiered, pruned_search, pruned_search_batch,
    pruned_search_batch_tiered, pruned_search_batch_tiered_timed, PrunedSearch, PrunedTiming,
};
