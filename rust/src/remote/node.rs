//! The shard node: `emdpar node` serves one corpus slice over the same
//! reactor + zero-copy wire path as the full server.
//!
//! A node is deliberately *not* a new server: it is the existing
//! [`crate::serve::ReactorServer`] wrapped around an engine whose dataset
//! is a [`crate::config::DatasetSpec::Slice`] — the Router-partition rows
//! of shard `s` of `S` — and whose corpus is a single local shard.  Every
//! protocol op therefore works on a node unchanged: `search` runs
//! shard-locally (returning *local* ids the coordinator maps back through
//! the partition), `add_docs` appends into the slice's own `EMDX` v3
//! segment chain, and `stats` / `telemetry` / `ping` answer as usual.
//!
//! [`node_config`] performs the rewrite; [`spawn_node`] runs a node on a
//! background thread for tests and embedded topologies, returning a
//! [`NodeHandle`] that stops the serve loop on drop.  The `emdpar node`
//! subcommand composes the same two pieces in the foreground.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::config::{Config, DatasetSpec, ShardParams};
use crate::coordinator::SearchEngine;
use crate::core::{EmdError, EmdResult};
use crate::emd_ensure;
use crate::serve::ReactorServer;

/// Rewrite a coordinator-style config into the node's view of shard
/// `shard` of `of`: the dataset becomes the corresponding
/// [`DatasetSpec::Slice`] and the corpus exactly one local shard.  The
/// coordinator's `Router` already partitioned the id space — a node
/// re-sharding its slice would misalign the local ids the coordinator maps
/// back to globals.  Any `remote` block is dropped (a node never fans out).
pub fn node_config(mut config: Config, shard: usize, of: usize) -> EmdResult<Config> {
    emd_ensure!(of >= 1, config, "node needs a total shard count >= 1");
    emd_ensure!(shard < of, config, "node shard {shard} out of range (serving 1 of {of})");
    config.dataset = match config.dataset {
        DatasetSpec::File(file) | DatasetSpec::Slice { file, .. } => {
            DatasetSpec::Slice { file, shard, of }
        }
        _ => {
            return Err(EmdError::config(
                "emdpar node serves a slice of a file-backed dataset; synthetic \
                 datasets have no shared base file to slice",
            ))
        }
    };
    let max_docs = config.sharded.map(|sp| sp.max_docs_per_shard).unwrap_or_else(|| {
        ShardParams::default().max_docs_per_shard
    });
    config.sharded = Some(ShardParams { shards: 1, max_docs_per_shard: max_docs });
    config.remote = None;
    config.validate()?;
    Ok(config)
}

/// A node serving on a background thread ([`spawn_node`]).  Dropping the
/// handle stops the serve loop and joins the thread.
pub struct NodeHandle {
    server: Arc<ReactorServer>,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl NodeHandle {
    /// The bound endpoint (ephemeral ports resolved).
    pub fn addr(&self) -> EmdResult<SocketAddr> {
        self.server.local_addr()
    }

    /// The node's engine (its corpus is the slice, under local ids).
    pub fn engine(&self) -> &Arc<SearchEngine> {
        self.server.engine()
    }

    /// The serving stack (readiness probe, admission budget).
    pub fn server(&self) -> &Arc<ReactorServer> {
        &self.server
    }

    /// Stop accepting, drain in-flight connections and join the loop.
    pub fn shutdown(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(thread) = self.thread.take() {
            let _ = thread.join();
        }
    }
}

impl Drop for NodeHandle {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Build and serve shard `shard` of `of` on `addr` (port 0 for ephemeral)
/// in a background thread.  Returns once the listener is bound — the
/// endpoint is live when this returns.
pub fn spawn_node(config: Config, shard: usize, of: usize, addr: &str) -> EmdResult<NodeHandle> {
    let config = node_config(config, shard, of)?;
    let engine = SearchEngine::from_config(config)?;
    let server = Arc::new(ReactorServer::bind(engine, addr)?);
    crate::log_info!(
        "node",
        "shard {shard}/{of}: {} docs on {}",
        server.engine().num_docs(),
        server.local_addr()?
    );
    let stop = Arc::new(AtomicBool::new(false));
    let thread = {
        let server = Arc::clone(&server);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            if let Err(e) = server.serve_until(&stop) {
                crate::log_warn!("node", "serve loop exited: {e}");
            }
        })
    };
    Ok(NodeHandle { server, stop, thread: Some(thread) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::path::PathBuf;

    fn write_base(name: &str) -> PathBuf {
        let config = Config {
            dataset: DatasetSpec::SynthText { n: 24, vocab: 120, dim: 6, seed: 11 },
            ..Default::default()
        };
        let ds = config.load_dataset().unwrap();
        let dir = std::env::temp_dir().join("emdpar_node_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        crate::data::save(&ds, &path).unwrap();
        path
    }

    #[test]
    fn node_config_slices_and_forces_one_local_shard() {
        let path = write_base("cfg.bin");
        let base = Config {
            dataset: DatasetSpec::File(path.clone()),
            sharded: Some(ShardParams { shards: 4, max_docs_per_shard: 123 }),
            ..Default::default()
        };
        let node = node_config(base, 1, 4).unwrap();
        assert_eq!(node.dataset, DatasetSpec::Slice { file: path, shard: 1, of: 4 });
        let sp = node.sharded.unwrap();
        assert_eq!(sp.shards, 1, "the coordinator's Router owns the partition");
        assert_eq!(sp.max_docs_per_shard, 123, "append policy carries over");
        assert!(node.remote.is_none(), "a node never fans out");

        let synth = Config::default();
        assert!(node_config(synth, 0, 2).is_err(), "synthetic bases cannot slice");
        let out_of_range =
            Config { dataset: DatasetSpec::File(write_base("cfg2.bin")), ..Default::default() };
        assert!(node_config(out_of_range, 2, 2).is_err());
    }

    #[test]
    fn spawned_node_answers_shard_local_searches() {
        let path = write_base("serve.bin");
        let full = Config { dataset: DatasetSpec::File(path.clone()), ..Default::default() }
            .load_dataset()
            .unwrap();
        let config = Config {
            dataset: DatasetSpec::File(path),
            threads: 2,
            linger_ms: 1,
            ..Default::default()
        };
        let node = spawn_node(config, 0, 2, "127.0.0.1:0").unwrap();
        assert_eq!(node.engine().num_docs(), 12, "shard 0 of 2 over 24 docs");
        let mut c = std::net::TcpStream::connect(node.addr().unwrap()).unwrap();
        c.write_all(b"{\"op\": \"ping\"}\n").unwrap();
        let mut line = String::new();
        BufReader::new(c.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains("pong"), "{line}");
        // a node search returns *local* ids: doc 0 of the slice is global 0
        // for shard 0, and it must find itself first
        use crate::util::json::Json;
        let q = full.histogram(0);
        let pairs = q
            .indices()
            .iter()
            .zip(q.weights())
            .map(|(&i, &w)| Json::Arr(vec![Json::Num(i as f64), Json::Num(w as f64)]))
            .collect();
        let req = Json::obj(vec![
            ("op", "search".into()),
            ("method", "rwmd".into()),
            ("l", 3.into()),
            ("query", Json::Arr(pairs)),
        ]);
        c.write_all(format!("{}\n", req.to_string_compact()).as_bytes()).unwrap();
        line.clear();
        BufReader::new(c.try_clone().unwrap()).read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{line}");
        let hits = resp.get("hits").and_then(Json::as_arr).unwrap();
        let first = hits[0].as_arr().unwrap();
        assert_eq!(first[1].as_usize(), Some(0), "{line}");
        node.shutdown();
    }
}
