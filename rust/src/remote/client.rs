//! The hedged fan-out RPC client: [`RemoteFleet`].
//!
//! A coordinator running with a [`Topology`] dispatches its `ShardFanout`
//! stage here instead of the in-process shard engines.  Each shard is
//! served by one or more replica nodes (`emdpar node`) speaking the same
//! newline-delimited JSON line protocol as the coordinator itself, so a
//! node is just a small single-shard server.
//!
//! Per shard dispatch is deadline-bounded and self-defending:
//!
//! * **pooling** — replica connections are kept in a small per-replica
//!   pool (`remote.pool`) and reused across queries; a stale pooled
//!   connection surfaces as a read error and flows through the retry path.
//! * **retry** — when every in-flight attempt for a shard has failed, the
//!   dispatch retries on the next replica (round-robin) after a jittered
//!   exponential backoff; an `{"error":"overloaded","retry_after_ms":N}`
//!   shed response replaces the backoff base with the node's own hint.
//! * **hedging** — with more than one replica, a second attempt races the
//!   first after a hedge delay: the observed per-shard p99 once enough
//!   samples exist ([`HEDGE_MIN_SAMPLES`], clamped to
//!   `[1ms, shard_timeout/2]`), the configured `remote.hedge_ms` before
//!   that.  The first response wins; the loser's socket is shut down so
//!   its worker dies instead of lingering.  `hedge_ms = 0` disables
//!   hedging.
//! * **deadline** — a shard that produces nothing within
//!   `remote.shard_timeout_ms` is dropped from the merge; the query
//!   completes over the surviving shards and is marked `partial`.
//!
//! Bit-identity: a node runs the same engine over the same `Router`
//! partition slice, so its top-ℓ set per query equals the in-process
//! shard's, local ids map to globals through the strictly-ascending
//! `Shard::globals` table (order-preserving), and [`TopL`] ordering is
//! value-based (`(distance, id)`, never insertion order) — re-pushing the
//! wire hits therefore reproduces the in-process accumulators exactly,
//! and the shard-order k-way merge does the rest.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::RemoteParams;
use crate::coordinator::metrics::LatencyHist;
use crate::coordinator::{merge_query_rows, Metrics, TopL};
use crate::core::{EmdError, EmdResult, Histogram, Method};
use crate::emd_ensure;
use crate::shard::{ShardedBatch, ShardedCorpus, ShardedSearch};
use crate::util::json::Json;

use super::Topology;

/// Latency samples required before the hedge delay switches from the
/// configured `hedge_ms` to the observed per-shard p99.
pub const HEDGE_MIN_SAMPLES: u64 = 32;

/// First-retry backoff base (doubles per round, jittered to `[b/2, b]`).
const BACKOFF_BASE_MS: u64 = 2;

/// Replica health, refreshed by every attempt and by [`RemoteFleet::refresh`].
const UNTRIED: u64 = 0;
const UP: u64 = 1;
const DOWN: u64 = 2;

/// One replica endpoint: a pooled-connection slot plus last-known health.
struct Replica {
    addr: String,
    pool: Mutex<Vec<TcpStream>>,
    state: AtomicU64,
}

/// One remote shard: its replicas and the latency history that drives the
/// adaptive hedge delay.
struct RemoteShard {
    id: usize,
    replicas: Vec<Replica>,
    latency: LatencyHist,
}

/// Per-query hits as a node returns them: (distance, node-local id).
type RemoteRows = Vec<Vec<(f32, usize)>>;

/// Why one attempt failed (carries the node's shed hint when present).
struct AttemptFail {
    msg: String,
    retry_after_ms: Option<u64>,
}

type AttemptResult = Result<(RemoteRows, TcpStream), AttemptFail>;
/// (attempt id, replica index, outcome).
type AttemptMsg = (u64, usize, AttemptResult);

/// A remote fan-out result: the same shape the in-process fan-out
/// produces, plus the partial-coverage marker.
pub struct RemoteBatch {
    pub batch: ShardedBatch,
    /// `true` when at least one shard was dropped from the merge (deadline
    /// or exhausted retries); results then cover the surviving shards only.
    pub partial: bool,
    /// Number of shards that contributed nothing.
    pub dropped: usize,
}

/// Connection-pooled, hedging, retrying client over every remote shard.
pub struct RemoteFleet {
    shards: Vec<RemoteShard>,
    params: RemoteParams,
    jitter: AtomicU64,
}

impl RemoteFleet {
    pub fn new(topology: &Topology, params: RemoteParams) -> RemoteFleet {
        let shards = (0..topology.num_shards())
            .map(|s| RemoteShard {
                id: s,
                replicas: topology
                    .replicas(s)
                    .iter()
                    .map(|a| Replica {
                        addr: a.clone(),
                        pool: Mutex::new(Vec::new()),
                        state: AtomicU64::new(UNTRIED),
                    })
                    .collect(),
                latency: LatencyHist::default(),
            })
            .collect();
        RemoteFleet { shards, params, jitter: AtomicU64::new(0x9E37_79B9_7F4A_7C15) }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn params(&self) -> &RemoteParams {
        &self.params
    }

    /// Fan one query batch out to every remote shard and k-way-merge the
    /// per-shard top-ℓ rows, exactly like the in-process
    /// [`crate::shard::search_batch`] route.  Shards that miss their
    /// deadline (after retries and hedging) are dropped from the merge and
    /// the batch is marked [`RemoteBatch::partial`]; only when *every*
    /// shard fails does the whole batch error.
    pub fn search_batch(
        &self,
        corpus: &ShardedCorpus,
        queries: &[Histogram],
        method: Method,
        l: usize,
        nprobe: Option<usize>,
        metrics: &Metrics,
    ) -> EmdResult<RemoteBatch> {
        emd_ensure!(
            self.shards.len() == corpus.num_shards(),
            config,
            "topology has {} shards but the corpus has {}",
            self.shards.len(),
            corpus.num_shards()
        );
        let nq = queries.len();
        let l = l.max(1);
        if nq == 0 {
            let batch = ShardedBatch {
                results: Vec::new(),
                merge_time: Duration::ZERO,
                fanout_time: Duration::ZERO,
                shard_times: Vec::new(),
            };
            return Ok(RemoteBatch { batch, partial: false, dropped: 0 });
        }

        // Serialize the request lines once; every shard receives the same
        // bytes.  `nprobe` is always explicit so a node never falls back
        // to its own default probe width: `None` (no index configured)
        // must stay exhaustive remotely too.
        let np_wire = nprobe.unwrap_or(usize::MAX >> 1).min(1 << 30);
        let lines = Arc::new(request_lines(queries, method, l, np_wire));

        let t_fan = Instant::now();
        let lanes: Vec<(Duration, Duration, Result<RemoteRows, String>)> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .shards
                    .iter()
                    .map(|shard| {
                        let lines = &lines;
                        scope.spawn(move || {
                            let begin = t_fan.elapsed();
                            let out = self.dispatch_shard(shard, lines, nq, metrics);
                            (begin, t_fan.elapsed().saturating_sub(begin), out)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("remote fan-out worker panicked"))
                    .collect()
            });
        let fanout_time = t_fan.elapsed();

        // Map node-local ids to globals; a dropped shard contributes empty
        // accumulators so the merge order (and thus tie-breaking) is
        // untouched.
        let mut shard_accs: Vec<Vec<TopL>> = Vec::with_capacity(self.shards.len());
        let mut shard_times = Vec::with_capacity(self.shards.len());
        let mut candidates = 0usize;
        let mut partial = false;
        let mut dropped = 0usize;
        let mut first_err: Option<String> = None;
        for (s, (begin, dur, out)) in lanes.into_iter().enumerate() {
            shard_times.push((begin, dur));
            let shard = &corpus.shards()[s];
            match out {
                Ok(rows) => {
                    let globals = shard.globals();
                    let mut accs = Vec::with_capacity(nq);
                    for row in &rows {
                        let mut acc = TopL::new(l);
                        for &(d, local) in row {
                            emd_ensure!(
                                local < globals.len(),
                                protocol,
                                "remote shard {s} returned local id {local} \
                                 out of range ({} docs)",
                                globals.len()
                            );
                            acc.push(d, globals[local] as usize);
                        }
                        accs.push(acc);
                    }
                    shard_accs.push(accs);
                    // The shard's contribution is exhaustive when it has no
                    // index or the probe covers every list (mirrors the
                    // in-process candidate accounting, which certification
                    // relies on).
                    let exhaustive = match shard.index() {
                        Some(ix) => np_wire >= ix.nlist(),
                        None => true,
                    };
                    if exhaustive {
                        candidates += shard.len();
                    }
                }
                Err(e) => {
                    crate::log_warn!("remote shard {s} dropped from merge: {e}");
                    partial = true;
                    dropped += 1;
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                    shard_accs.push(vec![TopL::new(l); nq]);
                }
            }
        }
        if dropped == self.shards.len() {
            return Err(EmdError::io(format!(
                "all {} remote shards failed: {}",
                self.shards.len(),
                first_err.unwrap_or_default()
            )));
        }

        let t_merge = Instant::now();
        let merged = merge_query_rows(&shard_accs, nq, l, corpus.engine_params().threads);
        let merge_time = t_merge.elapsed();

        let results = merged
            .into_iter()
            .map(|acc| {
                let hits = acc.into_sorted();
                let labels = hits.iter().map(|&(_, g)| corpus.label(g)).collect();
                ShardedSearch { hits, labels, candidates, lists_probed: 0, pruned: false }
            })
            .collect();

        let batch = ShardedBatch { results, merge_time, fanout_time, shard_times };
        Ok(RemoteBatch { batch, partial, dropped })
    }

    // -----------------------------------------------------------------
    // per-shard dispatch: retry + hedge + deadline
    // -----------------------------------------------------------------

    fn dispatch_shard(
        &self,
        shard: &RemoteShard,
        lines: &Arc<Vec<u8>>,
        nq: usize,
        metrics: &Metrics,
    ) -> Result<RemoteRows, String> {
        let started = Instant::now();
        let deadline = started + Duration::from_millis(self.params.shard_timeout_ms.max(1));
        let (tx, rx) = mpsc::channel::<AttemptMsg>();
        // In-flight attempts: (attempt id, replica index, cancel handle).
        let mut inflight: Vec<(u64, usize, TcpStream)> = Vec::new();
        let n_replicas = shard.replicas.len();
        let mut next_attempt: u64 = 0;
        let mut next_replica: usize = 0;
        let mut retries_left = self.params.retries;
        let mut hedged = false;
        let mut retry_hint: Option<u64> = None;
        let mut last_err = format!("shard {} has no reachable replica", shard.id);

        // Start one attempt on the first connectable replica (round-robin
        // so a retry moves on instead of hammering the same endpoint).
        let mut launch = |inflight: &mut Vec<(u64, usize, TcpStream)>,
                          next_attempt: &mut u64,
                          next_replica: &mut usize,
                          last_err: &mut String|
         -> bool {
            for _ in 0..n_replicas {
                let r = *next_replica % n_replicas;
                *next_replica += 1;
                match self.launch_attempt(shard, r, lines, nq, deadline, *next_attempt, &tx) {
                    Ok(cancel) => {
                        inflight.push((*next_attempt, r, cancel));
                        *next_attempt += 1;
                        return true;
                    }
                    Err(e) => {
                        shard.replicas[r].state.store(DOWN, Ordering::Relaxed);
                        *last_err = e;
                    }
                }
            }
            false
        };

        launch(&mut inflight, &mut next_attempt, &mut next_replica, &mut last_err);

        loop {
            if inflight.is_empty() {
                // Every attempt failed: back off and retry, or give up.
                if retries_left == 0 {
                    return Err(last_err);
                }
                retries_left -= 1;
                metrics.record_remote_retry();
                let round = self.params.retries - retries_left; // 1-based
                let base = retry_hint.take().unwrap_or(BACKOFF_BASE_MS << (round - 1).min(8));
                let backoff = Duration::from_millis(self.jittered_ms(base.max(1)));
                if deadline.saturating_duration_since(Instant::now()) <= backoff {
                    metrics.record_remote_timeout();
                    return Err(format!("{last_err} (shard {} deadline exhausted)", shard.id));
                }
                std::thread::sleep(backoff);
                launch(&mut inflight, &mut next_attempt, &mut next_replica, &mut last_err);
                continue;
            }

            let now = Instant::now();
            if now >= deadline {
                for (_, _, cancel) in &inflight {
                    cancel.shutdown(Shutdown::Both).ok();
                }
                metrics.record_remote_timeout();
                return Err(format!(
                    "shard {} timed out after {}ms (last error: {last_err})",
                    shard.id, self.params.shard_timeout_ms
                ));
            }
            let remaining = deadline - now;
            let can_hedge =
                !hedged && self.params.hedge_ms > 0 && n_replicas > 1 && inflight.len() == 1;
            let wait = if can_hedge { self.hedge_delay(shard).min(remaining) } else { remaining };

            match rx.recv_timeout(wait) {
                Ok((attempt, replica_idx, Ok((rows, stream)))) => {
                    // Winner: cancel every other racer so its worker dies.
                    for (a, _, cancel) in &inflight {
                        if *a != attempt {
                            cancel.shutdown(Shutdown::Both).ok();
                        }
                    }
                    self.checkin(&shard.replicas[replica_idx], stream);
                    shard.replicas[replica_idx].state.store(UP, Ordering::Relaxed);
                    shard.latency.record(started.elapsed());
                    return Ok(rows);
                }
                Ok((attempt, replica_idx, Err(fail))) => {
                    shard.replicas[replica_idx].state.store(DOWN, Ordering::Relaxed);
                    retry_hint = fail.retry_after_ms.or(retry_hint);
                    last_err = format!("{}: {}", shard.replicas[replica_idx].addr, fail.msg);
                    inflight.retain(|(a, _, _)| *a != attempt);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if can_hedge {
                        hedged = true;
                        if launch(
                            &mut inflight,
                            &mut next_attempt,
                            &mut next_replica,
                            &mut last_err,
                        ) {
                            metrics.record_remote_hedge();
                        }
                    }
                    // Otherwise the deadline check at the top of the loop
                    // fires on the next iteration.
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Unreachable (we hold a sender), but fail safe.
                    return Err(last_err);
                }
            }
        }
    }

    /// Check a connection out, spawn the attempt worker on it, and return
    /// the cancellation handle (a stream clone whose shutdown aborts the
    /// worker's blocking I/O).
    fn launch_attempt(
        &self,
        shard: &RemoteShard,
        replica_idx: usize,
        lines: &Arc<Vec<u8>>,
        nq: usize,
        deadline: Instant,
        attempt: u64,
        tx: &mpsc::Sender<AttemptMsg>,
    ) -> Result<TcpStream, String> {
        let replica = &shard.replicas[replica_idx];
        let remaining = deadline
            .saturating_duration_since(Instant::now())
            .max(Duration::from_millis(1));
        let stream = self.checkout(replica, self.connect_timeout().min(remaining))?;
        let cancel = stream
            .try_clone()
            .map_err(|e| format!("cannot clone socket for {}: {e}", replica.addr))?;
        let lines = Arc::clone(lines);
        let tx = tx.clone();
        std::thread::spawn(move || {
            let out = attempt_io(&stream, &lines, nq, deadline);
            let out = out.map(|rows| (rows, stream));
            tx.send((attempt, replica_idx, out)).ok();
        });
        Ok(cancel)
    }

    // -----------------------------------------------------------------
    // connection pool
    // -----------------------------------------------------------------

    fn connect_timeout(&self) -> Duration {
        Duration::from_millis((self.params.shard_timeout_ms / 4).clamp(10, 1000))
    }

    fn checkout(&self, replica: &Replica, timeout: Duration) -> Result<TcpStream, String> {
        if let Some(s) = replica.pool.lock().unwrap().pop() {
            return Ok(s);
        }
        let addr = replica
            .addr
            .to_socket_addrs()
            .map_err(|e| format!("cannot resolve {}: {e}", replica.addr))?
            .next()
            .ok_or_else(|| format!("no address for {}", replica.addr))?;
        let stream = TcpStream::connect_timeout(&addr, timeout)
            .map_err(|e| format!("cannot connect to {}: {e}", replica.addr))?;
        stream.set_nodelay(true).ok();
        Ok(stream)
    }

    fn checkin(&self, replica: &Replica, stream: TcpStream) {
        stream.set_read_timeout(None).ok();
        stream.set_write_timeout(None).ok();
        let mut pool = replica.pool.lock().unwrap();
        if pool.len() < self.params.pool {
            pool.push(stream);
        }
    }

    // -----------------------------------------------------------------
    // hedge delay + jitter
    // -----------------------------------------------------------------

    fn hedge_delay(&self, shard: &RemoteShard) -> Duration {
        let cap_us = (self.params.shard_timeout_ms.max(1) * 1000) / 2;
        let us = if shard.latency.count() >= HEDGE_MIN_SAMPLES {
            shard.latency.percentile_us(0.99).clamp(1_000, cap_us.max(1_000))
        } else {
            (self.params.hedge_ms * 1_000).max(1)
        };
        Duration::from_micros(us)
    }

    /// splitmix64 over an atomic counter: cheap decorrelation for backoff,
    /// deliberately not a real entropy source.
    fn next_jitter(&self) -> u64 {
        let mut z = self
            .jitter
            .fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed)
            .wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[base/2, base]` milliseconds.
    fn jittered_ms(&self, base: u64) -> u64 {
        let half = base / 2;
        half + self.next_jitter() % (base - half + 1)
    }

    // -----------------------------------------------------------------
    // health: status for telemetry, active probe for readiness
    // -----------------------------------------------------------------

    /// Ping replicas to refresh their health (`only_stale` limits the
    /// probe to replicas not currently known-up).  Each probe is one
    /// `{"op":"ping"}` round-trip on a pooled connection, bounded by the
    /// connect timeout.
    pub fn refresh(&self, only_stale: bool) {
        let timeout = self.connect_timeout();
        for shard in &self.shards {
            for replica in &shard.replicas {
                if only_stale && replica.state.load(Ordering::Relaxed) == UP {
                    continue;
                }
                match self.ping(replica, timeout) {
                    Ok(stream) => {
                        self.checkin(replica, stream);
                        replica.state.store(UP, Ordering::Relaxed);
                    }
                    Err(_) => replica.state.store(DOWN, Ordering::Relaxed),
                }
            }
        }
    }

    fn ping(&self, replica: &Replica, timeout: Duration) -> Result<TcpStream, String> {
        let stream = self.checkout(replica, timeout)?;
        stream.set_write_timeout(Some(timeout)).ok();
        stream.set_read_timeout(Some(timeout)).ok();
        let mut w = &stream;
        w.write_all(b"{\"op\":\"ping\"}\n")
            .and_then(|()| w.flush())
            .map_err(|e| format!("{}: ping write failed: {e}", replica.addr))?;
        let mut reader = BufReader::new(&stream);
        let mut line = String::new();
        let n = reader
            .read_line(&mut line)
            .map_err(|e| format!("{}: ping read failed: {e}", replica.addr))?;
        drop(reader);
        if n == 0 {
            return Err(format!("{}: connection closed on ping", replica.addr));
        }
        let ok = Json::parse(line.trim())
            .ok()
            .and_then(|j| j.get("ok").and_then(Json::as_bool))
            .unwrap_or(false);
        if ok {
            Ok(stream)
        } else {
            Err(format!("{}: bad ping response", replica.addr))
        }
    }

    /// Readiness probe: actively ping every not-known-up replica, then
    /// report the first shard with no live replica (if any).
    pub fn ready_error(&self) -> Option<String> {
        self.refresh(true);
        for shard in &self.shards {
            let up = shard
                .replicas
                .iter()
                .filter(|r| r.state.load(Ordering::Relaxed) == UP)
                .count();
            if up == 0 {
                return Some(format!(
                    "remote shard {} down (0/{} replicas reachable)",
                    shard.id,
                    shard.replicas.len()
                ));
            }
        }
        None
    }

    /// Passive connectivity snapshot for `{"op":"telemetry"}`:
    /// `connected` (every replica up), `degraded` (some up), `down`
    /// (none up).  Replicas never contacted are probed once first so the
    /// snapshot is meaningful before traffic arrives.
    pub fn status_json(&self) -> Json {
        self.refresh(true);
        let shards = self
            .shards
            .iter()
            .map(|shard| {
                let up = shard
                    .replicas
                    .iter()
                    .filter(|r| r.state.load(Ordering::Relaxed) == UP)
                    .count();
                let state = if up == shard.replicas.len() {
                    "connected"
                } else if up > 0 {
                    "degraded"
                } else {
                    "down"
                };
                let replicas = shard
                    .replicas
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("addr", r.addr.as_str().into()),
                            ("up", (r.state.load(Ordering::Relaxed) == UP).into()),
                            ("pooled", r.pool.lock().unwrap().len().into()),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("id", shard.id.into()),
                    ("state", state.into()),
                    ("replicas", Json::Arr(replicas)),
                    ("p99_us", (shard.latency.percentile_us(0.99) as usize).into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("shards", Json::Arr(shards)),
            ("shard_timeout_ms", (self.params.shard_timeout_ms as usize).into()),
            ("hedge_ms", (self.params.hedge_ms as usize).into()),
            ("pool", self.params.pool.into()),
        ])
    }
}

/// Serialize one request line per query (shared by every shard).
fn request_lines(queries: &[Histogram], method: Method, l: usize, np_wire: usize) -> Vec<u8> {
    let mut lines = Vec::with_capacity(queries.len() * 64);
    for q in queries {
        let pairs = q
            .indices()
            .iter()
            .zip(q.weights())
            .map(|(&i, &w)| Json::Arr(vec![Json::Num(i as f64), Json::Num(w as f64)]))
            .collect();
        let req = Json::obj(vec![
            ("op", "search".into()),
            ("method", method.name().into()),
            ("l", l.into()),
            ("nprobe", np_wire.into()),
            ("query", Json::Arr(pairs)),
        ]);
        lines.extend_from_slice(req.to_string_compact().as_bytes());
        lines.push(b'\n');
    }
    lines
}

/// One attempt's blocking I/O: pipeline every request line, then read one
/// response line per query.  Timeouts are rearmed to the remaining budget
/// before each blocking call so a stalled node cannot wedge the worker
/// past the deadline (the orchestrator additionally shuts the socket down
/// when it stops caring).
fn attempt_io(stream: &TcpStream, lines: &[u8], nq: usize, deadline: Instant) -> AttemptResult {
    let budget = |deadline: Instant| {
        deadline.saturating_duration_since(Instant::now()).max(Duration::from_millis(1))
    };
    stream.set_write_timeout(Some(budget(deadline))).ok();
    {
        let mut w = stream;
        w.write_all(lines)
            .and_then(|()| w.flush())
            .map_err(|e| plain_fail(format!("write failed: {e}")))?;
    }
    let mut reader = BufReader::new(stream);
    let mut rows = Vec::with_capacity(nq);
    let mut line = String::new();
    for _ in 0..nq {
        stream.set_read_timeout(Some(budget(deadline))).ok();
        line.clear();
        let n = reader
            .read_line(&mut line)
            .map_err(|e| plain_fail(format!("read failed: {e}")))?;
        if n == 0 {
            return Err(plain_fail("connection closed mid-response".into()));
        }
        rows.push(parse_hits(line.trim())?);
    }
    Ok(rows)
}

fn plain_fail(msg: String) -> AttemptFail {
    AttemptFail { msg, retry_after_ms: None }
}

/// Parse one response line into (distance, node-local id) hits.  Error
/// payloads keep their message (and shed hint); anything unparseable is a
/// structured "garbage response" failure, never a hang.
fn parse_hits(line: &str) -> Result<Vec<(f32, usize)>, AttemptFail> {
    let j = Json::parse(line)
        .map_err(|e| plain_fail(format!("garbage response: {e}")))?;
    if j.get("ok").and_then(Json::as_bool) != Some(true) {
        let msg = j
            .get("error")
            .and_then(Json::as_str)
            .unwrap_or("response without ok:true");
        return Err(AttemptFail {
            msg: format!("node error: {msg}"),
            retry_after_ms: j.get("retry_after_ms").and_then(Json::as_usize).map(|x| x as u64),
        });
    }
    let hits = j
        .get("hits")
        .and_then(Json::as_arr)
        .ok_or_else(|| plain_fail("response without hits".into()))?;
    let mut out = Vec::with_capacity(hits.len());
    for h in hits {
        let bad = || plain_fail(format!("malformed hit entry: {}", h.to_string_compact()));
        let row = h.as_arr().ok_or_else(bad)?;
        if row.len() < 2 {
            return Err(bad());
        }
        let d = row[0].as_f64().ok_or_else(bad)? as f32;
        let id = row[1].as_usize().ok_or_else(bad)?;
        out.push((d, id));
    }
    Ok(out)
}
