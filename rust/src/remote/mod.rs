//! Distributed corpus: remote shard nodes behind a hedged fan-out RPC.
//!
//! The sharded corpus ([`crate::shard`]) fans a query batch out over
//! in-process shard engines and k-way-merges per-shard top-ℓ rows.  This
//! module moves the fan-out across machine boundaries with **the same
//! merge and the same bits**:
//!
//! * [`topology`] — [`Topology`]: the JSON manifest mapping shard id →
//!   replica endpoints, loaded by the coordinator when
//!   [`crate::config::RemoteParams::topology`] is set.
//! * [`node`] — the `emdpar node` subcommand: the existing
//!   [`crate::serve::ReactorServer`] over a [`crate::config::DatasetSpec::Slice`]
//!   engine (one Router-partition slice, one local shard), so every wire
//!   op — shard-local `search`, `add_docs` into the slice's `EMDX` v3
//!   segment chain, `stats`, health — works on a node unchanged.
//! * [`client`] — [`RemoteFleet`]: connection-pooled fan-out with
//!   per-shard deadlines, jittered retry/backoff that honors the nodes'
//!   `retry_after_ms` overload hints, and hedged requests (a second
//!   replica raced after a p99-derived delay; first answer wins, the
//!   loser's socket is shut down).  A shard that misses its deadline is
//!   dropped from the merge and the response carries `partial: true`
//!   instead of failing the batch.
//!
//! Bit-identity: a node scores its slice through the same
//! [`crate::lc::LcEngine`] pipeline as an in-process shard, local hit ids
//! map back through the Router partition's global id vector, and
//! [`crate::coordinator::merge_query_rows`] merges value-ordered top-ℓ
//! rows — so at full probe the remote route reproduces the in-process
//! fan-out exactly, hedged or not.

pub mod client;
pub mod node;
pub mod topology;

pub use client::{RemoteBatch, RemoteFleet, HEDGE_MIN_SAMPLES};
pub use node::{node_config, spawn_node, NodeHandle};
pub use topology::Topology;
