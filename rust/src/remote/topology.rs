//! The topology manifest: shard id → replica endpoints.
//!
//! A coordinator running with `remote.topology` set loads one of these JSON
//! files and dispatches its `ShardFanout` stage over TCP instead of
//! in-process shard engines.  The manifest is deliberately tiny:
//!
//! ```json
//! {"shards": [
//!   {"id": 0, "replicas": ["127.0.0.1:7001", "127.0.0.1:7101"]},
//!   {"id": 1, "replicas": ["127.0.0.1:7002"]}
//! ]}
//! ```
//!
//! Shard ids must be dense (`0..num_shards`, each exactly once) and match
//! the coordinator corpus' shard count — the per-shard top-ℓ merge runs in
//! shard order, so the manifest's id space *is* the merge order.  Every
//! shard needs at least one replica; additional replicas serve hedged
//! requests ([`crate::remote::RemoteFleet`]).

use std::path::Path;

use crate::core::{EmdError, EmdResult};
use crate::emd_ensure;
use crate::util::json::Json;

/// A validated topology: `replicas[s]` are shard `s`'s endpoints.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    replicas: Vec<Vec<String>>,
}

impl Topology {
    /// Build from per-shard replica lists (`lists[s]` = shard `s`).
    pub fn new(lists: Vec<Vec<String>>) -> EmdResult<Topology> {
        emd_ensure!(!lists.is_empty(), config, "topology needs at least one shard");
        for (s, replicas) in lists.iter().enumerate() {
            emd_ensure!(
                !replicas.is_empty(),
                config,
                "topology shard {s} needs at least one replica endpoint"
            );
            for addr in replicas {
                emd_ensure!(
                    !addr.trim().is_empty(),
                    config,
                    "topology shard {s} has an empty replica endpoint"
                );
            }
        }
        Ok(Topology { replicas: lists })
    }

    /// Parse the manifest object (see module docs).
    pub fn from_json(j: &Json) -> EmdResult<Topology> {
        let shards = j
            .get("shards")
            .and_then(Json::as_arr)
            .ok_or_else(|| EmdError::config("topology needs a 'shards' array"))?;
        emd_ensure!(!shards.is_empty(), config, "topology needs at least one shard");
        let mut lists: Vec<Option<Vec<String>>> = vec![None; shards.len()];
        for entry in shards {
            let id = entry
                .get("id")
                .and_then(Json::as_usize)
                .ok_or_else(|| EmdError::config("topology shard needs an integer 'id'"))?;
            emd_ensure!(
                id < lists.len(),
                config,
                "topology shard id {id} out of range: ids must be dense 0..{}",
                lists.len()
            );
            emd_ensure!(
                lists[id].is_none(),
                config,
                "topology shard id {id} appears more than once"
            );
            let arr = entry
                .get("replicas")
                .and_then(Json::as_arr)
                .ok_or_else(|| EmdError::config("topology shard needs a 'replicas' array"))?;
            let mut replicas = Vec::with_capacity(arr.len());
            for a in arr {
                let addr = a
                    .as_str()
                    .ok_or_else(|| EmdError::config("topology replicas are address strings"))?;
                replicas.push(addr.to_string());
            }
            lists[id] = Some(replicas);
        }
        // dense + each-exactly-once is guaranteed by the range/dup checks
        Topology::new(lists.into_iter().map(|l| l.expect("dense ids")).collect())
    }

    /// Load and parse a manifest file.
    pub fn from_file(path: &Path) -> EmdResult<Topology> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| EmdError::io(format!("cannot read topology {path:?}: {e}")))?;
        let j = Json::parse(&text)
            .map_err(|e| EmdError::config(format!("bad topology JSON in {path:?}: {e}")))?;
        Topology::from_json(&j)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "shards",
            Json::Arr(
                self.replicas
                    .iter()
                    .enumerate()
                    .map(|(id, replicas)| {
                        Json::obj(vec![
                            ("id", id.into()),
                            (
                                "replicas",
                                Json::Arr(
                                    replicas.iter().map(|a| Json::Str(a.clone())).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        )])
    }

    pub fn num_shards(&self) -> usize {
        self.replicas.len()
    }

    /// Shard `s`'s replica endpoints (primary first).
    pub fn replicas(&self, shard: usize) -> &[String] {
        &self.replicas[shard]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_round_trips() {
        let text = r#"{"shards": [
            {"id": 1, "replicas": ["127.0.0.1:7002"]},
            {"id": 0, "replicas": ["127.0.0.1:7001", "127.0.0.1:7101"]}
        ]}"#;
        let topo = Topology::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(topo.num_shards(), 2);
        assert_eq!(topo.replicas(0), ["127.0.0.1:7001", "127.0.0.1:7101"]);
        assert_eq!(topo.replicas(1), ["127.0.0.1:7002"]);
        let back =
            Topology::from_json(&Json::parse(&topo.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back, topo);
    }

    #[test]
    fn rejects_sparse_duplicate_or_empty() {
        for bad in [
            r#"{"shards": []}"#,
            r#"{"shards": [{"id": 1, "replicas": ["a:1"]}]}"#,
            r#"{"shards": [{"id": 0, "replicas": ["a:1"]}, {"id": 0, "replicas": ["a:2"]}]}"#,
            r#"{"shards": [{"id": 0, "replicas": []}]}"#,
            r#"{"shards": [{"id": 0, "replicas": [" "]}]}"#,
            r#"{"noshards": true}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Topology::from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn file_loader_reports_clean_errors() {
        let dir = std::env::temp_dir().join("emdpar_topology_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("topo.json");
        std::fs::write(&path, "{not json").unwrap();
        assert!(Topology::from_file(&path).is_err());
        std::fs::write(&path, r#"{"shards": [{"id": 0, "replicas": ["h:1"]}]}"#).unwrap();
        assert_eq!(Topology::from_file(&path).unwrap().num_shards(), 1);
        assert!(Topology::from_file(&dir.join("missing.json")).is_err());
        std::fs::remove_file(&path).ok();
    }
}
