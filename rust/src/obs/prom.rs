//! Prometheus text exposition (format version 0.0.4) of the aggregate
//! engine metrics — served by the `metrics` wire op and the
//! `--metrics-addr` mini HTTP listener.
//!
//! Counters map to `emdpar_*_total`; the log-bucketed [`LatencyHist`]s map
//! to native Prometheus histograms with cumulative `_bucket{le=...}`
//! series (upper bounds are the power-of-two bucket edges), `_sum` and
//! `_count`.

use std::fmt::Write as _;
use std::sync::atomic::Ordering;

use crate::coordinator::engine::SearchEngine;
use crate::coordinator::metrics::{LatencyHist, Metrics};
use crate::obs::agg::{key_label, TelemetrySnapshot};
use crate::obs::audit::Auditor;
use crate::obs::trace::TraceCollector;

/// Render the full exposition page.  `tracer` is optional so callers
/// without a collector (unit tests, the legacy server) can still expose
/// the counter set.
pub fn render(metrics: &Metrics, tracer: Option<&TraceCollector>) -> String {
    let mut out = String::with_capacity(4096);
    let counters: &[(&str, &str, u64)] = &[
        ("queries", "Queries answered", metrics.queries.load(Ordering::Relaxed)),
        ("batches", "Plan executions (dispatch groups)", metrics.batches.load(Ordering::Relaxed)),
        ("errors", "Request errors", metrics.errors.load(Ordering::Relaxed)),
        (
            "distance_evals",
            "Distance evaluations",
            metrics.distance_evals.load(Ordering::Relaxed),
        ),
        (
            "index_queries",
            "Queries routed through the IVF index",
            metrics.index_queries.load(Ordering::Relaxed),
        ),
        (
            "lists_probed",
            "Inverted lists visited",
            metrics.lists_probed.load(Ordering::Relaxed),
        ),
        (
            "candidates_scored",
            "Candidates scored by index-routed queries",
            metrics.candidates_scored.load(Ordering::Relaxed),
        ),
        (
            "cascade_queries",
            "Queries answered through a cascade plan",
            metrics.cascade_queries.load(Ordering::Relaxed),
        ),
        (
            "reranked",
            "Candidates rescored by rerank stages",
            metrics.reranked_total.load(Ordering::Relaxed),
        ),
        (
            "shard_batches",
            "Sharded fan-out dispatches",
            metrics.shard_batches.load(Ordering::Relaxed),
        ),
        ("merge_us", "Microseconds spent in cross-shard merges", metrics.merge_us()),
        ("admitted", "Searches admitted into the bridge", metrics.admitted.load(Ordering::Relaxed)),
        ("shed", "Searches shed at admission", metrics.shed.load(Ordering::Relaxed)),
        (
            "deadline_expired",
            "Searches shed on an expired deadline",
            metrics.deadline_expired.load(Ordering::Relaxed),
        ),
        (
            "remote_hedges",
            "Hedged replica requests launched by the remote fan-out",
            metrics.remote_hedges.load(Ordering::Relaxed),
        ),
        (
            "remote_retries",
            "Remote shard attempts retried after a failure",
            metrics.remote_retries.load(Ordering::Relaxed),
        ),
        (
            "remote_timeouts",
            "Remote shards dropped from a merge on deadline",
            metrics.remote_timeouts.load(Ordering::Relaxed),
        ),
    ];
    for &(name, help, value) in counters {
        let _ = writeln!(out, "# HELP emdpar_{name}_total {help}");
        let _ = writeln!(out, "# TYPE emdpar_{name}_total counter");
        let _ = writeln!(out, "emdpar_{name}_total {value}");
    }
    let _ = writeln!(out, "# HELP emdpar_pruned_fraction Database fraction not scored by index-routed queries");
    let _ = writeln!(out, "# TYPE emdpar_pruned_fraction gauge");
    let _ = writeln!(out, "emdpar_pruned_fraction {}", metrics.pruned_fraction());
    if let Some(t) = tracer {
        let _ = writeln!(out, "# HELP emdpar_trace_spans_total Spans pushed into the trace ring");
        let _ = writeln!(out, "# TYPE emdpar_trace_spans_total counter");
        let _ = writeln!(out, "emdpar_trace_spans_total {}", t.total());
        let _ = writeln!(out, "# HELP emdpar_trace_dropped_total Spans lost to ring wraparound");
        let _ = writeln!(out, "# TYPE emdpar_trace_dropped_total counter");
        let _ = writeln!(out, "emdpar_trace_dropped_total {}", t.dropped());
    }
    histogram(&mut out, "queue_wait_us", "Enqueue to batch-drain wait", &metrics.queue_wait);
    histogram(&mut out, "execute_us", "Engine execute time per dispatch group", &metrics.execute);
    histogram(&mut out, "e2e_us", "Enqueue to response-serialized end-to-end time", &metrics.e2e);
    out
}

/// The full page for a live engine: [`render`]'s counter/histogram set
/// plus the sliding-window workload gauges and audited-recall gauges.
/// This is the body behind `--metrics-addr` in `emdpar serve` and the
/// `metrics` wire op.
pub fn render_engine(engine: &SearchEngine) -> String {
    let metrics = engine.metrics();
    let mut out = render(&metrics, Some(engine.tracer()));
    telemetry_gauges(&mut out, &engine.telemetry().snapshot());
    audit_gauges(&mut out, engine.auditor());
    out
}

/// Windowed per-workload gauges from one telemetry snapshot: one
/// `{workload="<label>"}` series per resolved parameter combination,
/// covering the retained window ring (rates, not lifetime counters).
pub fn telemetry_gauges(out: &mut String, snap: &TelemetrySnapshot) {
    let _ = writeln!(out, "# HELP emdpar_telemetry_span_ms Wall span covered by the telemetry window ring");
    let _ = writeln!(out, "# TYPE emdpar_telemetry_span_ms gauge");
    let _ = writeln!(out, "emdpar_telemetry_span_ms {}", snap.span_ms);
    let _ = writeln!(out, "# HELP emdpar_telemetry_shed_unkeyed Admission sheds in the window (shed before a workload key exists)");
    let _ = writeln!(out, "# TYPE emdpar_telemetry_shed_unkeyed gauge");
    let _ = writeln!(out, "emdpar_telemetry_shed_unkeyed {}", snap.shed_unkeyed);
    let labeled: Vec<(String, &crate::obs::agg::WorkloadWindow, f64)> = snap
        .workloads
        .iter()
        .map(|(key, w, qps)| (key_label(key), w, *qps))
        .collect();
    workload_gauge(out, "workload_qps", "Windowed queries per second", labeled.iter().map(|(l, _, qps)| (l.as_str(), *qps)));
    workload_gauge(out, "workload_queries", "Queries answered in the window", labeled.iter().map(|(l, w, _)| (l.as_str(), w.queries as f64)));
    workload_gauge(out, "workload_deadline_expired", "Deadline sheds in the window", labeled.iter().map(|(l, w, _)| (l.as_str(), w.deadline_expired as f64)));
    workload_gauge(out, "workload_errors", "Per-query failures in the window", labeled.iter().map(|(l, w, _)| (l.as_str(), w.errors as f64)));
    workload_gauge(out, "workload_p99_us", "Windowed p99 execute latency, microseconds", labeled.iter().map(|(l, w, _)| (l.as_str(), w.latency.percentile_us(0.99) as f64)));
    workload_gauge(out, "workload_lists_per_query", "Mean inverted lists probed per query in the window", labeled.iter().map(|(l, w, _)| (l.as_str(), w.lists_probed as f64 / w.queries.max(1) as f64)));
    workload_gauge(out, "workload_rerank_fraction", "Fraction of windowed candidates rescored by rerank stages", labeled.iter().map(|(l, w, _)| (l.as_str(), w.reranked as f64 / w.candidates_scored.max(1) as f64)));
}

/// Online recall-audit gauges: the sampling rate, the audit pipeline's own
/// counters, and the per-workload recall estimates.
pub fn audit_gauges(out: &mut String, auditor: &Auditor) {
    let _ = writeln!(out, "# HELP emdpar_audit_sample Recall-audit sampling rate, 1-in-N (0 = off)");
    let _ = writeln!(out, "# TYPE emdpar_audit_sample gauge");
    let _ = writeln!(out, "emdpar_audit_sample {}", auditor.sample());
    let _ = writeln!(out, "# HELP emdpar_audits_total Sampled queries replayed at full probe");
    let _ = writeln!(out, "# TYPE emdpar_audits_total counter");
    let _ = writeln!(out, "emdpar_audits_total {}", auditor.audited());
    let _ = writeln!(out, "# HELP emdpar_audit_lost_total Samples dropped at the audit queue plus failed replays");
    let _ = writeln!(out, "# TYPE emdpar_audit_lost_total counter");
    let _ = writeln!(out, "emdpar_audit_lost_total {}", auditor.lost());
    let est = auditor.estimates();
    let labeled: Vec<(String, crate::obs::audit::RecallStat)> =
        est.iter().map(|(key, s)| (key_label(key), *s)).collect();
    workload_gauge(out, "audit_recall", "Mean audited recall against the full-probe replay", labeled.iter().map(|(l, s)| (l.as_str(), s.mean())));
    workload_gauge(out, "audit_last_recall", "Most recent audited recall", labeled.iter().map(|(l, s)| (l.as_str(), s.last_recall)));
    workload_gauge(out, "audit_min_recall", "Worst audited recall observed", labeled.iter().map(|(l, s)| (l.as_str(), s.min_recall)));
}

/// One gauge family with a `workload` label per series.
fn workload_gauge<'a>(
    out: &mut String,
    name: &str,
    help: &str,
    series: impl Iterator<Item = (&'a str, f64)>,
) {
    let _ = writeln!(out, "# HELP emdpar_{name} {help}");
    let _ = writeln!(out, "# TYPE emdpar_{name} gauge");
    for (label, value) in series {
        let _ = writeln!(out, "emdpar_{name}{{workload=\"{label}\"}} {value}");
    }
}

/// Emit one histogram: cumulative `le` buckets, `+Inf`, `_sum`, `_count`.
fn histogram(out: &mut String, name: &str, help: &str, h: &LatencyHist) {
    let _ = writeln!(out, "# HELP emdpar_{name} {help}");
    let _ = writeln!(out, "# TYPE emdpar_{name} histogram");
    let mut cumulative = 0u64;
    for (i, count) in h.bucket_counts().into_iter().enumerate() {
        cumulative += count;
        match LatencyHist::bucket_bound(i) {
            Some(le) => {
                let _ = writeln!(out, "emdpar_{name}_bucket{{le=\"{le}\"}} {cumulative}");
            }
            None => {
                let _ = writeln!(out, "emdpar_{name}_bucket{{le=\"+Inf\"}} {cumulative}");
            }
        }
    }
    let _ = writeln!(out, "emdpar_{name}_sum {}", h.sum_us());
    let _ = writeln!(out, "emdpar_{name}_count {}", h.count());
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// A minimal exposition-format grammar check shared with the CI lint:
    /// every line is a comment or `name[{labels}] value`.
    pub fn lint(text: &str) -> Result<(), String> {
        let name_ok = |s: &str| {
            !s.is_empty()
                && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        };
        for (ln, line) in text.lines().enumerate() {
            if line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
                continue;
            }
            let (series, value) = line
                .rsplit_once(' ')
                .ok_or_else(|| format!("line {}: no value: {line:?}", ln + 1))?;
            value
                .parse::<f64>()
                .map_err(|_| format!("line {}: bad value {value:?}", ln + 1))?;
            let base = match series.split_once('{') {
                Some((base, labels)) => {
                    if !labels.ends_with('}') {
                        return Err(format!("line {}: unclosed labels", ln + 1));
                    }
                    base
                }
                None => series,
            };
            if !name_ok(base) {
                return Err(format!("line {}: bad metric name {base:?}", ln + 1));
            }
        }
        Ok(())
    }

    #[test]
    fn exposition_passes_the_format_lint() {
        let m = Metrics::new();
        m.record_query(Duration::from_micros(120), 40);
        m.record_probe(4, 25, 100);
        m.e2e.record(Duration::from_micros(300));
        let t = TraceCollector::new(32);
        let text = render(&m, Some(&t));
        lint(&text).unwrap();
        assert!(text.contains("emdpar_queries_total 1"));
        assert!(text.contains("emdpar_trace_dropped_total 0"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_inf() {
        let m = Metrics::new();
        // 10 µs -> le=16 bucket; 5000 µs -> le=8192 bucket
        m.e2e.record_us(10);
        m.e2e.record_us(5000);
        let text = render(&m, None);
        assert!(text.contains("emdpar_e2e_us_bucket{le=\"16\"} 1"));
        assert!(text.contains("emdpar_e2e_us_bucket{le=\"8192\"} 2"));
        assert!(text.contains("emdpar_e2e_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("emdpar_e2e_us_sum 5010"));
        assert!(text.contains("emdpar_e2e_us_count 2"));
        // cumulative counts never decrease within one histogram
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("emdpar_e2e_us_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "non-cumulative bucket: {line}");
            last = v;
        }
    }

    #[test]
    fn telemetry_and_audit_gauges_pass_the_lint() {
        use crate::coordinator::plan::{GroupKey, QueryStats};
        use crate::core::Method;
        let key = GroupKey {
            method: Method::Rwmd,
            l: 10,
            nprobe: Some(4),
            cascade: None,
            threads: Some(2),
        };
        let t = crate::obs::agg::Telemetry::new(1000);
        t.record(
            &key,
            &QueryStats {
                queries: 3,
                lists_probed: 12,
                candidates_scored: 75,
                reranked: 15,
                total_us: 300,
                ..QueryStats::default()
            },
        );
        t.record_shed();
        let a = Auditor::new(64);
        a.publish(&key, 1.0, 250);
        let mut out = String::new();
        telemetry_gauges(&mut out, &t.snapshot());
        audit_gauges(&mut out, &a);
        lint(&out).unwrap();
        assert!(out.contains("emdpar_workload_qps{workload=\"rwmd_l10_np4\"}"), "{out}");
        assert!(out.contains("emdpar_workload_queries{workload=\"rwmd_l10_np4\"} 3"), "{out}");
        assert!(out.contains("emdpar_workload_rerank_fraction{workload=\"rwmd_l10_np4\"} 0.2"), "{out}");
        assert!(out.contains("emdpar_telemetry_shed_unkeyed 1"), "{out}");
        assert!(out.contains("emdpar_audit_sample 64"), "{out}");
        assert!(out.contains("emdpar_audits_total 1"), "{out}");
        assert!(out.contains("emdpar_audit_recall{workload=\"rwmd_l10_np4\"} 1"), "{out}");
    }

    #[test]
    fn lint_rejects_malformed_lines() {
        assert!(lint("emdpar_ok_total 1").is_ok());
        assert!(lint("no-dashes-allowed 1").is_err());
        assert!(lint("emdpar_x_total notanumber").is_err());
        assert!(lint("emdpar_x_bucket{le=\"2\" 3").is_err());
    }
}
