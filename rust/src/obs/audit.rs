//! Online recall auditing: deterministically sample 1-in-N production
//! queries and replay them off the hot path at full probe.
//!
//! The serving bridge asks [`Auditor::should_sample`] once per member
//! (with sampling off this is a single branch on an immutable field — the
//! serving path stays byte-identical), clones the sampled query plus the
//! hit ids it served, and hands the job to a bounded channel.  One
//! background worker replays each job through the normal
//! `SearchEngine::execute` with the probe width forced exhaustive — the
//! same override a certified cascade uses, so the replay is the full-probe
//! reference the bit-identity tests assert against, and the `DocView`
//! snapshotting inside `execute` means audits never block corpus appends.
//! The served ids are scored against the replay with
//! [`crate::eval::recall_at`], and per-workload estimates accumulate in a
//! keyed list for the telemetry op, the Prometheus gauges and `/readyz`
//! consumers.
//!
//! The channel is lossy by design: if the worker falls behind, new samples
//! are dropped (and counted) rather than ever back-pressuring serving.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::engine::SearchEngine;
use crate::coordinator::plan::GroupKey;
use crate::core::Histogram;
use crate::eval::recall_at;
use crate::util::json::Json;

use super::agg::{key_json, key_label};

/// Bounded audit queue: behind this, samples drop (counted) instead of
/// blocking the dispatcher.
const QUEUE_DEPTH: usize = 256;

/// The probe-width override that collapses every pruning route to the
/// exhaustive sweep (mirrors the certified-cascade override in the
/// planner).
const FULL_PROBE: usize = usize::MAX >> 1;

/// One sampled production query awaiting replay.
pub struct AuditJob {
    pub key: GroupKey,
    pub query: Histogram,
    /// Doc ids the production response served, request order.
    pub served: Vec<usize>,
}

/// Accumulated recall estimate for one workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecallStat {
    pub audits: u64,
    pub recall_sum: f64,
    pub min_recall: f64,
    pub last_recall: f64,
    /// Total replay wall micros (the audit pipeline's own cost).
    pub replay_us: u64,
}

impl RecallStat {
    pub fn mean(&self) -> f64 {
        if self.audits == 0 {
            0.0
        } else {
            self.recall_sum / self.audits as f64
        }
    }
}

/// The sampler + estimate store.  One per engine; the worker thread is
/// spawned by the serving bridge ([`spawn_worker`]).
pub struct Auditor {
    /// Sample 1 in `sample` members; 0 = auditing off.
    sample: u64,
    counter: AtomicU64,
    audited: AtomicU64,
    /// Samples dropped at the full queue, plus replay failures.
    lost: AtomicU64,
    tx: Option<SyncSender<AuditJob>>,
    rx: Mutex<Option<Receiver<AuditJob>>>,
    estimates: Mutex<Vec<(GroupKey, RecallStat)>>,
}

impl Auditor {
    pub fn new(sample: u64) -> Auditor {
        let (tx, rx) = if sample == 0 {
            (None, None)
        } else {
            let (tx, rx) = sync_channel(QUEUE_DEPTH);
            (Some(tx), Some(rx))
        };
        Auditor {
            sample,
            counter: AtomicU64::new(0),
            audited: AtomicU64::new(0),
            lost: AtomicU64::new(0),
            tx,
            rx: Mutex::new(rx),
            estimates: Mutex::new(Vec::new()),
        }
    }

    /// The configured 1-in-N rate (0 = off).
    pub fn sample(&self) -> u64 {
        self.sample
    }

    /// Deterministic sampler: every `sample`-th call returns true.  Off
    /// (`sample == 0`) this is one branch on an immutable field — no
    /// atomics touched.
    #[inline]
    pub fn should_sample(&self) -> bool {
        self.sample != 0 && self.counter.fetch_add(1, Ordering::Relaxed) % self.sample == 0
    }

    /// Enqueue one sampled job; drops (and counts) when the worker is
    /// behind or auditing is off.
    pub fn submit(&self, job: AuditJob) {
        match &self.tx {
            Some(tx) if tx.try_send(job).is_ok() => {}
            _ => {
                self.lost.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Hand the job queue to the worker (first caller wins; the bridge may
    /// be spawned more than once per engine).
    pub fn take_receiver(&self) -> Option<Receiver<AuditJob>> {
        self.rx.lock().unwrap().take()
    }

    /// Fold one replay outcome into `key`'s estimate.
    pub fn publish(&self, key: &GroupKey, recall: f64, replay_us: u64) {
        self.audited.fetch_add(1, Ordering::Relaxed);
        let mut est = self.estimates.lock().unwrap();
        match est.iter_mut().find(|(k, _)| k == key) {
            Some((_, s)) => {
                s.audits += 1;
                s.recall_sum += recall;
                s.min_recall = s.min_recall.min(recall);
                s.last_recall = recall;
                s.replay_us += replay_us;
            }
            None => est.push((
                *key,
                RecallStat {
                    audits: 1,
                    recall_sum: recall,
                    min_recall: recall,
                    last_recall: recall,
                    replay_us,
                },
            )),
        }
    }

    /// Count one failed replay.
    pub fn record_failure(&self) {
        self.lost.fetch_add(1, Ordering::Relaxed);
    }

    /// Completed audits.
    pub fn audited(&self) -> u64 {
        self.audited.load(Ordering::Relaxed)
    }

    /// Samples lost (queue overflow + replay failures).
    pub fn lost(&self) -> u64 {
        self.lost.load(Ordering::Relaxed)
    }

    /// Per-workload estimates, heaviest (most-audited) first.
    pub fn estimates(&self) -> Vec<(GroupKey, RecallStat)> {
        let mut est = self.estimates.lock().unwrap().clone();
        est.sort_by(|a, b| b.1.audits.cmp(&a.1.audits));
        est
    }

    /// The telemetry op's `audit` sub-object.
    pub fn to_json(&self) -> Json {
        let workloads = self
            .estimates()
            .iter()
            .map(|(key, s)| {
                Json::obj(vec![
                    ("key", key_json(key)),
                    ("label", key_label(key).into()),
                    ("audits", (s.audits as usize).into()),
                    ("recall", s.mean().into()),
                    ("min_recall", s.min_recall.into()),
                    ("last_recall", s.last_recall.into()),
                    ("replay_us", (s.replay_us as usize).into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("sample", (self.sample as usize).into()),
            ("audited", (self.audited() as usize).into()),
            ("lost", (self.lost() as usize).into()),
            ("workloads", Json::Arr(workloads)),
        ])
    }
}

/// Spawn the replay worker for `engine`'s auditor.  Returns `None` when
/// auditing is off or a worker already owns the queue.  The worker holds
/// only a `Weak` engine reference so it can never keep the engine alive;
/// it exits when the engine drops (checked on a 200 ms idle tick) or the
/// sender side closes.
pub fn spawn_worker(engine: &Arc<SearchEngine>) -> Option<JoinHandle<()>> {
    let auditor = engine.auditor_arc();
    let rx = auditor.take_receiver()?;
    let weak: Weak<SearchEngine> = Arc::downgrade(engine);
    Some(
        std::thread::Builder::new()
            .name("emdpar-audit".into())
            .spawn(move || loop {
                match rx.recv_timeout(Duration::from_millis(200)) {
                    Ok(job) => {
                        let Some(engine) = weak.upgrade() else { break };
                        replay(&engine, &auditor, job);
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        if weak.upgrade().is_none() {
                            break;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            })
            .expect("spawn audit worker"),
    )
}

/// Replay one sampled query at full probe and score the served ids
/// against the exhaustive reference.
fn replay(engine: &SearchEngine, auditor: &Auditor, job: AuditJob) {
    let req = job.key.request(vec![job.query]).nprobe(FULL_PROBE);
    let t0 = Instant::now();
    match engine.execute(&req) {
        Ok(resp) if !resp.results.is_empty() => {
            let truth: Vec<usize> =
                resp.results[0].hits.iter().map(|&(_, id)| id).collect();
            let recall = recall_at(&truth, &job.served);
            let us = t0.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
            auditor.publish(&job.key, recall, us);
        }
        _ => auditor.record_failure(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Method;

    fn key() -> GroupKey {
        GroupKey {
            method: Method::Rwmd,
            l: 5,
            nprobe: Some(2),
            cascade: None,
            threads: Some(1),
        }
    }

    #[test]
    fn sampling_off_touches_no_atomics() {
        let a = Auditor::new(0);
        for _ in 0..100 {
            assert!(!a.should_sample());
        }
        assert_eq!(a.counter.load(Ordering::Relaxed), 0, "off path must not count");
        // submits with no queue are counted as lost, not panicking
        a.submit(AuditJob { key: key(), query: Histogram::from_pairs(vec![(0, 1.0)]), served: vec![] });
        assert_eq!(a.lost(), 1);
    }

    #[test]
    fn sampler_is_deterministic_one_in_n() {
        let a = Auditor::new(4);
        let picks: Vec<bool> = (0..12).map(|_| a.should_sample()).collect();
        let expect: Vec<bool> = (0..12).map(|i| i % 4 == 0).collect();
        assert_eq!(picks, expect);
    }

    #[test]
    fn estimates_accumulate_per_workload() {
        let a = Auditor::new(1);
        a.publish(&key(), 1.0, 100);
        a.publish(&key(), 0.5, 100);
        let other = GroupKey { l: 9, ..key() };
        a.publish(&other, 0.25, 10);
        let est = a.estimates();
        assert_eq!(est.len(), 2);
        assert_eq!(est[0].0, key(), "most-audited workload first");
        assert_eq!(est[0].1.audits, 2);
        assert!((est[0].1.mean() - 0.75).abs() < 1e-12);
        assert_eq!(est[0].1.min_recall, 0.5);
        assert_eq!(est[0].1.last_recall, 0.5);
        let j = a.to_json();
        assert_eq!(j.get("audited").and_then(Json::as_usize), Some(3));
        let w = &j.get("workloads").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(w.get("audits").and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn queue_overflow_drops_instead_of_blocking() {
        let a = Auditor::new(1);
        // nobody drains the queue: the first QUEUE_DEPTH fit, the rest drop
        for _ in 0..QUEUE_DEPTH + 5 {
            a.submit(AuditJob {
                key: key(),
                query: Histogram::from_pairs(vec![(0, 1.0)]),
                served: vec![1],
            });
        }
        assert_eq!(a.lost(), 5);
    }
}
