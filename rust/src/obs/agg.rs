//! Sliding-window workload telemetry: per-[`GroupKey`] rates over a ring
//! of fixed-duration windows.
//!
//! The serving bridge records one entry per dispatched group (plus shed /
//! deadline events), keyed by the same plan-normalized [`GroupKey`] the
//! batcher groups on — so "workload" here means exactly one resolved
//! parameter combination (method × ℓ × probe width × cascade × threads).
//! Storage is a bounded ring of [`WINDOW_RETAIN`] windows behind one
//! mutex; the hot path takes that lock once per *dispatch group* (not per
//! query), after a single relaxed-atomic `armed` check.  Unarmed, the
//! entire layer is one branch — the serving path stays byte-identical.
//!
//! Snapshots aggregate the retained windows into per-workload QPS,
//! shed/deadline counts, per-stage micros, latency percentiles (via
//! [`HistSnapshot`] window deltas) and probe/candidate/rerank fractions —
//! the `{"op":"telemetry"}` payload, the Prometheus gauge source, and the
//! training data the ROADMAP's cost-model planner will fit against.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::metrics::HistSnapshot;
use crate::coordinator::plan::{GroupKey, QueryStats};
use crate::util::json::Json;

/// Windows retained by the ring (closed windows + the live one).  With the
/// default 1 s window this is an 8 s sliding view.
pub const WINDOW_RETAIN: usize = 8;

/// One workload's accumulator inside one window.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkloadWindow {
    /// Queries answered (summed over dispatch groups).
    pub queries: u64,
    /// Dispatch groups executed.
    pub batches: u64,
    /// Members shed because their deadline expired before dispatch.
    pub deadline_expired: u64,
    /// Per-query failures surfaced on this workload's retry path.
    pub errors: u64,
    /// Inverted lists visited by index-routed members.
    pub lists_probed: u64,
    /// Stage-1 candidates scored.
    pub candidates_scored: u64,
    /// Candidates rescored by rerank stages.
    pub reranked: u64,
    /// Queries carrying a Theorem-2 exactness certificate.
    pub certified: u64,
    /// Per-stage wall micros, summed over dispatches.
    pub prune_us: u64,
    pub score_us: u64,
    pub fanout_us: u64,
    pub merge_us: u64,
    pub rerank_us: u64,
    pub total_us: u64,
    /// Per-query amortized execute latency (the window's `LatencyHist`
    /// delta, recorded as a plain-value snapshot under the ring mutex).
    pub latency: HistSnapshot,
}

impl WorkloadWindow {
    fn absorb(&mut self, stats: &QueryStats) {
        let n = stats.queries.max(1) as u64;
        self.queries += stats.queries as u64;
        self.batches += 1;
        self.lists_probed += stats.lists_probed as u64;
        self.candidates_scored += stats.candidates_scored as u64;
        self.reranked += stats.reranked as u64;
        self.certified += stats.certified.iter().filter(|&&c| c).count() as u64;
        self.prune_us += stats.prune_us;
        self.score_us += stats.score_us;
        self.fanout_us += stats.fanout_us;
        self.merge_us += stats.merge_us;
        self.rerank_us += stats.rerank_us;
        self.total_us += stats.total_us;
        let per_query = stats.total_us / n;
        for _ in 0..stats.queries {
            self.latency.record_us(per_query);
        }
    }

    fn add(&mut self, other: &WorkloadWindow) {
        self.queries += other.queries;
        self.batches += other.batches;
        self.deadline_expired += other.deadline_expired;
        self.errors += other.errors;
        self.lists_probed += other.lists_probed;
        self.candidates_scored += other.candidates_scored;
        self.reranked += other.reranked;
        self.certified += other.certified;
        self.prune_us += other.prune_us;
        self.score_us += other.score_us;
        self.fanout_us += other.fanout_us;
        self.merge_us += other.merge_us;
        self.rerank_us += other.rerank_us;
        self.total_us += other.total_us;
        self.latency.add(&other.latency);
    }
}

/// One fixed-duration window: a keyed Vec of workload accumulators (the
/// same linear-scan idiom the batcher uses — `GroupKey` is deliberately
/// un-`Hash`ed) plus events that arrive before a request resolves a key.
#[derive(Debug, Default)]
struct Window {
    /// `now_ms / window_ms` at open time.
    index: u64,
    groups: Vec<(GroupKey, WorkloadWindow)>,
    /// Admission sheds (no parsed request, so no workload key).
    shed_unkeyed: u64,
}

impl Window {
    fn group(&mut self, key: &GroupKey) -> &mut WorkloadWindow {
        if let Some(i) = self.groups.iter().position(|(k, _)| k == key) {
            return &mut self.groups[i].1;
        }
        self.groups.push((*key, WorkloadWindow::default()));
        &mut self.groups.last_mut().unwrap().1
    }
}

/// Aggregated view over the retained windows at one instant.
#[derive(Debug, Clone)]
pub struct TelemetrySnapshot {
    pub window_ms: u64,
    /// Windows aggregated (≤ [`WINDOW_RETAIN`]).
    pub windows: usize,
    /// Wall span the aggregate covers, ms (QPS denominator).
    pub span_ms: u64,
    pub shed_unkeyed: u64,
    /// Per-workload aggregates with their windowed QPS.
    pub workloads: Vec<(GroupKey, WorkloadWindow, f64)>,
}

impl TelemetrySnapshot {
    pub fn to_json(&self) -> Json {
        let workloads = self
            .workloads
            .iter()
            .map(|(key, w, qps)| {
                let queries = w.queries.max(1) as f64;
                let candidates = w.candidates_scored.max(1) as f64;
                Json::obj(vec![
                    ("key", key_json(key)),
                    ("label", key_label(key).into()),
                    ("qps", (*qps).into()),
                    ("queries", (w.queries as usize).into()),
                    ("batches", (w.batches as usize).into()),
                    ("deadline_expired", (w.deadline_expired as usize).into()),
                    ("errors", (w.errors as usize).into()),
                    ("lists_probed", (w.lists_probed as usize).into()),
                    ("candidates_scored", (w.candidates_scored as usize).into()),
                    ("reranked", (w.reranked as usize).into()),
                    ("certified", (w.certified as usize).into()),
                    ("lists_per_query", (w.lists_probed as f64 / queries).into()),
                    ("candidates_per_query", (w.candidates_scored as f64 / queries).into()),
                    ("rerank_fraction", (w.reranked as f64 / candidates).into()),
                    (
                        "stage_us",
                        Json::obj(vec![
                            ("prune", (w.prune_us as usize).into()),
                            ("score", (w.score_us as usize).into()),
                            ("fanout", (w.fanout_us as usize).into()),
                            ("merge", (w.merge_us as usize).into()),
                            ("rerank", (w.rerank_us as usize).into()),
                            ("total", (w.total_us as usize).into()),
                        ]),
                    ),
                    ("latency", w.latency.to_json()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("window_ms", (self.window_ms as usize).into()),
            ("windows", self.windows.into()),
            ("span_ms", (self.span_ms as usize).into()),
            ("shed_unkeyed", (self.shed_unkeyed as usize).into()),
            ("workloads", Json::Arr(workloads)),
        ])
    }
}

/// The store: an `armed` gate in front of a mutex-guarded window ring.
pub struct Telemetry {
    armed: AtomicBool,
    window_ms: u64,
    epoch: Instant,
    inner: Mutex<VecDeque<Window>>,
}

impl Telemetry {
    /// `window_ms = 0` builds the store disarmed (recording is a single
    /// branch); any later [`Telemetry::set_armed`] uses a 1 s window.
    pub fn new(window_ms: u64) -> Telemetry {
        Telemetry {
            armed: AtomicBool::new(window_ms > 0),
            window_ms: if window_ms == 0 { 1000 } else { window_ms },
            epoch: Instant::now(),
            inner: Mutex::new(VecDeque::new()),
        }
    }

    /// The hot-path guard: one relaxed load.
    #[inline]
    pub fn armed(&self) -> bool {
        self.armed.load(Ordering::Relaxed)
    }

    pub fn set_armed(&self, on: bool) {
        self.armed.store(on, Ordering::Relaxed);
    }

    pub fn window_ms(&self) -> u64 {
        self.window_ms
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis().min(u128::from(u64::MAX)) as u64
    }

    /// The window for "now", rotating and bounding the ring as needed.
    fn current<'a>(&self, ring: &'a mut VecDeque<Window>) -> &'a mut Window {
        let index = self.now_ms() / self.window_ms;
        if ring.back().map(|w| w.index) != Some(index) {
            ring.push_back(Window { index, ..Window::default() });
            while ring.len() > WINDOW_RETAIN {
                ring.pop_front();
            }
        }
        ring.back_mut().unwrap()
    }

    /// Record one dispatched group's accounting under its workload key.
    pub fn record(&self, key: &GroupKey, stats: &QueryStats) {
        if !self.armed() {
            return;
        }
        let mut ring = self.inner.lock().unwrap();
        self.current(&mut ring).group(key).absorb(stats);
    }

    /// Record one deadline-expired shed for `key`'s workload.
    pub fn record_deadline(&self, key: &GroupKey) {
        if !self.armed() {
            return;
        }
        let mut ring = self.inner.lock().unwrap();
        self.current(&mut ring).group(key).deadline_expired += 1;
    }

    /// Record one per-query failure for `key`'s workload.
    pub fn record_error(&self, key: &GroupKey) {
        if !self.armed() {
            return;
        }
        let mut ring = self.inner.lock().unwrap();
        self.current(&mut ring).group(key).errors += 1;
    }

    /// Record one admission shed (no request parsed yet, so no key).
    pub fn record_shed(&self) {
        if !self.armed() {
            return;
        }
        let mut ring = self.inner.lock().unwrap();
        self.current(&mut ring).shed_unkeyed += 1;
    }

    /// Aggregate the retained windows.  Workloads sort by descending query
    /// volume so the heaviest workload leads the exposition.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let ring = self.inner.lock().unwrap();
        let mut workloads: Vec<(GroupKey, WorkloadWindow)> = Vec::new();
        let mut shed_unkeyed = 0;
        for win in ring.iter() {
            shed_unkeyed += win.shed_unkeyed;
            for (key, w) in &win.groups {
                match workloads.iter_mut().find(|(k, _)| k == key) {
                    Some((_, agg)) => agg.add(w),
                    None => workloads.push((*key, w.clone())),
                }
            }
        }
        // span = from the oldest retained window's open edge to now; the
        // live window contributes its elapsed fraction, so QPS is not
        // diluted by the unfilled remainder
        let span_ms = match ring.front() {
            Some(front) => (self.now_ms() - front.index * self.window_ms).max(1),
            None => self.window_ms,
        };
        let secs = span_ms as f64 / 1e3;
        let mut out: Vec<(GroupKey, WorkloadWindow, f64)> = workloads
            .into_iter()
            .map(|(k, w)| {
                let qps = w.queries as f64 / secs;
                (k, w, qps)
            })
            .collect();
        out.sort_by(|a, b| b.1.queries.cmp(&a.1.queries));
        TelemetrySnapshot {
            window_ms: self.window_ms,
            windows: ring.len(),
            span_ms,
            shed_unkeyed,
            workloads: out,
        }
    }
}

/// Protocol form of a workload key, mirroring the request fields it was
/// resolved from.
pub fn key_json(key: &GroupKey) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("method", key.method.name().into()),
        ("l", key.l.into()),
    ];
    pairs.push(("nprobe", match key.nprobe {
        Some(np) => np.into(),
        None => Json::Null,
    }));
    if let Some((rerank, overfetch, certified)) = key.cascade {
        pairs.push((
            "cascade",
            Json::obj(vec![
                ("rerank", rerank.name().into()),
                ("overfetch", overfetch.into()),
                ("certified", certified.into()),
            ]),
        ));
    }
    if let Some(t) = key.threads {
        pairs.push(("threads", t.into()));
    }
    Json::obj(pairs)
}

/// Compact single-token workload label, safe for a Prometheus label value
/// (lowercase + digits + `_`), e.g. `rwmd_l10_np4` or
/// `rwmd_l5_full_cas_emd_x8_cert`.
pub fn key_label(key: &GroupKey) -> String {
    let mut s = format!("{}_l{}", key.method.name().to_lowercase(), key.l);
    s = s.replace('-', "_");
    match key.nprobe {
        Some(np) => s.push_str(&format!("_np{np}")),
        None => s.push_str("_full"),
    }
    if let Some((rerank, overfetch, certified)) = key.cascade {
        s.push_str(&format!(
            "_cas_{}_x{overfetch}",
            rerank.name().to_lowercase().replace('-', "_")
        ));
        if certified {
            s.push_str("_cert");
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Method;

    fn key(l: usize) -> GroupKey {
        GroupKey { method: Method::Rwmd, l, nprobe: Some(4), cascade: None, threads: Some(2) }
    }

    fn stats(queries: usize, total_us: u64) -> QueryStats {
        QueryStats {
            queries,
            lists_probed: 4 * queries,
            candidates_scored: 25 * queries,
            reranked: 5 * queries,
            total_us,
            score_us: total_us / 2,
            ..QueryStats::default()
        }
    }

    #[test]
    fn unarmed_store_records_nothing() {
        let t = Telemetry::new(0);
        assert!(!t.armed());
        t.record(&key(10), &stats(3, 300));
        t.record_shed();
        t.record_deadline(&key(10));
        let snap = t.snapshot();
        assert!(snap.workloads.is_empty());
        assert_eq!(snap.shed_unkeyed, 0);
        // arming later activates the 1 s fallback window
        t.set_armed(true);
        t.record(&key(10), &stats(1, 50));
        assert_eq!(t.snapshot().workloads.len(), 1);
        assert_eq!(t.window_ms(), 1000);
    }

    #[test]
    fn groups_accumulate_by_workload_key() {
        let t = Telemetry::new(1000);
        t.record(&key(10), &stats(3, 300));
        t.record(&key(10), &stats(2, 100));
        t.record(&key(5), &stats(1, 40));
        t.record_deadline(&key(5));
        t.record_shed();
        let snap = t.snapshot();
        assert_eq!(snap.workloads.len(), 2);
        // heaviest workload first
        let (k0, w0, qps) = &snap.workloads[0];
        assert_eq!(k0.l, 10);
        assert_eq!(w0.queries, 5);
        assert_eq!(w0.batches, 2);
        assert_eq!(w0.lists_probed, 20);
        assert_eq!(w0.candidates_scored, 125);
        assert_eq!(w0.latency.count, 5);
        assert!(*qps > 0.0);
        let (k1, w1, _) = &snap.workloads[1];
        assert_eq!(k1.l, 5);
        assert_eq!(w1.deadline_expired, 1);
        assert_eq!(snap.shed_unkeyed, 1);
    }

    #[test]
    fn window_ring_rotates_and_stays_bounded() {
        let t = Telemetry::new(1);
        for _ in 0..3 * WINDOW_RETAIN {
            t.record(&key(10), &stats(1, 10));
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let snap = t.snapshot();
        assert!(snap.windows <= WINDOW_RETAIN, "{} windows retained", snap.windows);
        // old windows aged out: the aggregate holds fewer than all records
        assert!(snap.workloads[0].1.queries < 3 * WINDOW_RETAIN as u64);
    }

    #[test]
    fn snapshot_json_carries_rates_and_stage_micros() {
        let t = Telemetry::new(1000);
        t.record(&key(10), &stats(4, 400));
        let j = t.snapshot().to_json();
        assert_eq!(j.get("window_ms").and_then(Json::as_usize), Some(1000));
        let w = &j.get("workloads").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(w.get("queries").and_then(Json::as_usize), Some(4));
        assert_eq!(w.get("lists_per_query").and_then(Json::as_f64), Some(4.0));
        assert_eq!(w.get("rerank_fraction").and_then(Json::as_f64), Some(0.2));
        assert_eq!(
            w.get("stage_us").and_then(|s| s.get("total")).and_then(Json::as_usize),
            Some(400)
        );
        assert_eq!(
            w.get("latency").and_then(|l| l.get("count")).and_then(Json::as_usize),
            Some(4)
        );
        assert_eq!(w.get("label").and_then(Json::as_str), Some("rwmd_l10_np4"));
    }

    #[test]
    fn key_labels_are_prometheus_safe() {
        let cascaded = GroupKey {
            method: Method::Rwmd,
            l: 5,
            nprobe: None,
            cascade: Some((Method::Act { k: 3 }, 8, true)),
            threads: Some(1),
        };
        for k in [key(10), cascaded] {
            let label = key_label(&k);
            assert!(
                label.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
                "unsafe label {label:?}"
            );
        }
        // Method::Act{k}.name() prints ACT-(k-1)
        assert_eq!(key_label(&cascaded), "rwmd_l5_full_cas_act_2_x8_cert");
    }
}
