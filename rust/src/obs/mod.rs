//! Observability: end-to-end query tracing and metrics exposition.
//!
//! Layers:
//! * [`trace`] — the span model: a lock-free ring-buffer [`TraceCollector`]
//!   with bounded memory and drop counting, plus the per-request
//!   [`TraceSession`] recorder the execute paths write into,
//! * [`chrome`] — Chrome trace-event JSON export (`chrome://tracing` /
//!   Perfetto loadable) of the collector ring,
//! * [`agg`] — sliding-window per-workload telemetry (QPS, shed/deadline
//!   counts, stage micros, latency deltas) keyed by the batcher's
//!   [`crate::coordinator::GroupKey`],
//! * [`audit`] — online recall auditing: 1-in-N sampled production queries
//!   replayed at full probe off the hot path, per-workload recall@ℓ,
//! * [`prom`] — Prometheus text exposition (version 0.0.4) of the
//!   aggregate [`crate::coordinator::Metrics`] plus the windowed telemetry
//!   and audited-recall gauges,
//! * [`http`] — a dependency-free mini HTTP listener serving `/metrics`,
//!   `/healthz` and `/readyz` (`emdpar serve --metrics-addr`).
//!
//! Tracing is opt-in per request (`SearchRequest::trace`) or armed globally
//! by the slow-query log (`ServeParams::slow_query_us` /
//! `EMDPAR_SLOW_QUERY_US`).  When neither is active the execute paths only
//! take a handful of stage-boundary `Instant` timestamps (to fill the
//! always-on per-stage `QueryStats` fields) and skip span recording after a
//! single relaxed atomic check — results are bit-identical either way.

pub mod agg;
pub mod audit;
pub mod chrome;
pub mod http;
pub mod prom;
pub mod trace;

pub use trace::{SpanName, SpanRec, TraceCollector, TraceSession, TraceSnapshot, ROOT_SPAN};
