//! Observability: end-to-end query tracing and metrics exposition.
//!
//! Layers:
//! * [`trace`] — the span model: a lock-free ring-buffer [`TraceCollector`]
//!   with bounded memory and drop counting, plus the per-request
//!   [`TraceSession`] recorder the execute paths write into,
//! * [`chrome`] — Chrome trace-event JSON export (`chrome://tracing` /
//!   Perfetto loadable) of the collector ring,
//! * [`prom`] — Prometheus text exposition (version 0.0.4) of the
//!   aggregate [`crate::coordinator::Metrics`],
//! * [`http`] — a dependency-free mini HTTP listener serving `/metrics`
//!   (`emdpar serve --metrics-addr`).
//!
//! Tracing is opt-in per request (`SearchRequest::trace`) or armed globally
//! by the slow-query log (`ServeParams::slow_query_us` /
//! `EMDPAR_SLOW_QUERY_US`).  When neither is active the execute paths only
//! take a handful of stage-boundary `Instant` timestamps (to fill the
//! always-on per-stage `QueryStats` fields) and skip span recording after a
//! single relaxed atomic check — results are bit-identical either way.

pub mod chrome;
pub mod http;
pub mod prom;
pub mod trace;

pub use trace::{SpanName, SpanRec, TraceCollector, TraceSession, TraceSnapshot, ROOT_SPAN};
