//! Chrome trace-event JSON export of recorded spans.
//!
//! The emitted object follows the Trace Event Format's "JSON Object
//! Format": a `traceEvents` array of complete (`"ph":"X"`) events with
//! microsecond `ts`/`dur`.  Extra top-level keys are ignored by the
//! loaders, so the `trace` wire op's response line — which also carries
//! `"ok"` and `"dropped"` — loads directly into `chrome://tracing` or
//! Perfetto.

use crate::obs::trace::SpanRec;
use crate::util::json::Json;

/// Render spans as a Trace-Event JSON object (single line).
/// `dropped` reports ring-wraparound losses alongside the events.
pub fn render(spans: &[SpanRec], dropped: u64) -> Json {
    let events: Vec<Json> = spans.iter().map(event).collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("dropped", Json::Num(dropped as f64)),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// One complete event.  `pid` groups everything under one process row;
/// `tid` separates fan-out lanes (shard index, connection token) so
/// parallel children render stacked instead of overlapping.
fn event(s: &SpanRec) -> Json {
    Json::obj(vec![
        ("name", Json::Str(s.name_str().to_string())),
        ("ph", Json::Str("X".to_string())),
        ("ts", Json::Num(s.start_us as f64)),
        ("dur", Json::Num(s.dur_us as f64)),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(s.tid as f64)),
        (
            "args",
            Json::obj(vec![
                ("trace_id", Json::Num(s.trace_id as f64)),
                ("span_id", Json::Num(s.span_id as f64)),
                ("parent_id", Json::Num(s.parent_id as f64)),
            ]),
        ),
    ])
}

/// Render the per-response span timeline (session-relative starts) as a
/// plain JSON array — the `"trace"` field of a traced search response.
pub fn timeline(spans: &[SpanRec]) -> Json {
    Json::Arr(
        spans
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::Str(s.name_str().to_string())),
                    ("id", Json::Num(s.span_id as f64)),
                    ("parent", Json::Num(s.parent_id as f64)),
                    ("tid", Json::Num(s.tid as f64)),
                    ("start_us", Json::Num(s.start_us as f64)),
                    ("dur_us", Json::Num(s.dur_us as f64)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::SpanName;

    fn span(id: u16, parent: u16, name: SpanName) -> SpanRec {
        SpanRec {
            trace_id: 9,
            span_id: id,
            parent_id: parent,
            name: name as u16,
            tid: 0,
            start_us: 5 * id as u64,
            dur_us: 4,
        }
    }

    #[test]
    fn export_is_valid_json_with_complete_events() {
        let spans =
            [span(1, 0, SpanName::Request), span(2, 1, SpanName::Prune), span(3, 1, SpanName::Score)];
        let j = render(&spans, 2);
        let text = j.to_string_compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(back.get("dropped").unwrap().as_usize(), Some(2));
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3);
        for ev in events {
            assert_eq!(ev.get("ph").unwrap().as_str(), Some("X"));
            assert!(ev.get("ts").is_some() && ev.get("dur").is_some());
            assert!(ev.get("pid").is_some() && ev.get("tid").is_some());
            assert!(ev.get("args").unwrap().get("trace_id").is_some());
        }
        assert_eq!(events[1].get("name").unwrap().as_str(), Some("prune"));
    }

    #[test]
    fn timeline_carries_parent_links() {
        let spans = [span(1, 0, SpanName::Request), span(2, 1, SpanName::Merge)];
        let arr = timeline(&spans);
        let items = arr.as_arr().unwrap();
        assert_eq!(items[1].get("parent").unwrap().as_usize(), Some(1));
        assert_eq!(items[1].get("name").unwrap().as_str(), Some("merge"));
    }
}
