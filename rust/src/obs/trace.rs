//! Lock-free span tracer: a bounded ring-buffer collector plus the
//! per-request session recorder.
//!
//! Every span is four machine words (trace id, packed ids/name, start,
//! duration) written into a fixed-capacity ring guarded by a per-slot
//! sequence counter (a seqlock built entirely from `AtomicU64`s — no
//! `unsafe`).  Writers claim a slot with a single `fetch_add` ticket;
//! readers skip slots whose sequence changes mid-read.  When the ring
//! wraps, the oldest spans are overwritten and counted as dropped —
//! memory stays bounded no matter how long the process traces.
//!
//! Span names are indices into a static table ([`SpanName`]) so a record
//! never carries a pointer that could tear.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

/// Stage names a span can carry.  The discriminant is the wire id; the
/// static table below maps it back to a label.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum SpanName {
    /// Whole-request root span (one per traced `execute`).
    Request = 0,
    /// IVF probe: selecting candidate lists per query.
    Prune = 1,
    /// Phase-1/2 scoring of the candidate set.
    Score = 2,
    /// Parallel dispatch over the sharded corpus.
    ShardFanout = 3,
    /// One shard's probe+score work (child of `ShardFanout`; `tid` = shard).
    Shard = 4,
    /// k-way merge of per-shard top-ℓ rows.
    Merge = 5,
    /// Bound-certified cascade rerank of stage-1 survivors.
    CascadeRerank = 6,
    /// Exact-f32 rescoring after a compressed stage 1.
    ExactRerank = 7,
    /// Batcher linger: first enqueue until the group dispatched.
    BatchGather = 8,
    /// Bridge dispatch of one grouped `engine.execute`.
    Dispatch = 9,
    /// Reactor connection read phase (`tid` = connection token).
    ConnRead = 10,
    /// Reactor connection write phase (`tid` = connection token).
    ConnWrite = 11,
}

/// Label table indexed by the `SpanName` discriminant.
pub const SPAN_NAMES: &[&str] = &[
    "request",
    "prune",
    "score",
    "shard_fanout",
    "shard",
    "merge",
    "cascade_rerank",
    "exact_rerank",
    "batch_gather",
    "dispatch",
    "conn_read",
    "conn_write",
];

impl SpanName {
    pub fn as_str(self) -> &'static str {
        SPAN_NAMES[self as u16 as usize]
    }

    /// Reverse lookup for ids read back out of the ring; unknown ids (from
    /// a torn wrap-race record) fall back to `Request`.
    pub fn from_u16(id: u16) -> SpanName {
        match id {
            1 => SpanName::Prune,
            2 => SpanName::Score,
            3 => SpanName::ShardFanout,
            4 => SpanName::Shard,
            5 => SpanName::Merge,
            6 => SpanName::CascadeRerank,
            7 => SpanName::ExactRerank,
            8 => SpanName::BatchGather,
            9 => SpanName::Dispatch,
            10 => SpanName::ConnRead,
            11 => SpanName::ConnWrite,
            _ => SpanName::Request,
        }
    }
}

/// One recorded span.  `start_us` is relative to the session root when the
/// record sits in a response timeline, and relative to the collector epoch
/// when it sits in the ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanRec {
    pub trace_id: u64,
    /// 1-based span id unique within the trace; the root is always 1.
    pub span_id: u16,
    /// Parent span id; 0 marks the root.
    pub parent_id: u16,
    /// Index into [`SPAN_NAMES`].
    pub name: u16,
    /// Lane: shard index / connection token for fan-out spans, else 0.
    pub tid: u16,
    pub start_us: u64,
    pub dur_us: u64,
}

impl SpanRec {
    pub fn name_str(&self) -> &'static str {
        SpanName::from_u16(self.name).as_str()
    }

    fn pack_ids(&self) -> u64 {
        ((self.span_id as u64) << 48)
            | ((self.parent_id as u64) << 32)
            | ((self.name as u64) << 16)
            | self.tid as u64
    }

    fn from_words(w: [u64; 4]) -> SpanRec {
        SpanRec {
            trace_id: w[0],
            span_id: (w[1] >> 48) as u16,
            parent_id: (w[1] >> 32) as u16,
            name: (w[1] >> 16) as u16,
            tid: w[1] as u16,
            start_us: w[2],
            dur_us: w[3],
        }
    }
}

/// One ring slot: a sequence counter plus the four record words.  Odd
/// sequence = write in progress; readers accept a slot only when the
/// sequence is even and unchanged across the read.
struct Slot {
    seq: AtomicU64,
    words: [AtomicU64; 4],
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            words: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        }
    }
}

/// A consistent copy of the ring at one point in time.
#[derive(Clone, Debug, Default)]
pub struct TraceSnapshot {
    /// Readable spans sorted by start time (collector-epoch relative).
    pub spans: Vec<SpanRec>,
    /// Spans overwritten by ring wraparound since the last reset.
    pub dropped: u64,
    /// Total spans ever pushed.
    pub total: u64,
}

/// Bounded lock-free span sink shared by every layer of the engine.
pub struct TraceCollector {
    epoch: Instant,
    slots: Box<[Slot]>,
    head: AtomicU64,
    enabled: AtomicBool,
    next_trace: AtomicU64,
    /// `dropped` as of the last wraparound WARN (see
    /// [`TraceCollector::warn_on_new_drops`]).
    warned_dropped: AtomicU64,
}

impl TraceCollector {
    /// `capacity` is clamped to at least 16 slots; memory is
    /// `capacity * 40` bytes, fixed for the collector's lifetime.
    pub fn new(capacity: usize) -> TraceCollector {
        let cap = capacity.max(16);
        TraceCollector {
            epoch: Instant::now(),
            slots: (0..cap).map(|_| Slot::empty()).collect(),
            head: AtomicU64::new(0),
            enabled: AtomicBool::new(false),
            next_trace: AtomicU64::new(1),
            warned_dropped: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// The hot-path guard: a single relaxed load.  Execute paths skip all
    /// span recording when this is false.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Arm (or disarm) ambient span collection — flipped on by the first
    /// traced request or a configured slow-query threshold.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Microseconds since the collector epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    pub fn next_trace_id(&self) -> u64 {
        self.next_trace.fetch_add(1, Ordering::Relaxed)
    }

    /// Push one span into the ring, overwriting the oldest when full.
    ///
    /// Writer protocol: claim a monotonically increasing ticket, mark the
    /// slot odd, write the words, mark it even with the ticket's own
    /// sequence.  `fetch_max` keeps the sequence monotonic when a lapped
    /// writer races a faster one on the same slot; the reader's
    /// same-sequence recheck rejects any mixed read.  (Two writers a full
    /// ring apart can interleave word writes — last-writer-wins on a
    /// diagnostic record, never on search results.)
    pub fn push(&self, rec: SpanRec) {
        let ticket = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket % self.slots.len() as u64) as usize];
        let odd = ticket * 2 + 1;
        slot.seq.fetch_max(odd, Ordering::SeqCst);
        slot.words[0].store(rec.trace_id, Ordering::Relaxed);
        slot.words[1].store(rec.pack_ids(), Ordering::Relaxed);
        slot.words[2].store(rec.start_us, Ordering::Relaxed);
        slot.words[3].store(rec.dur_us, Ordering::Relaxed);
        slot.seq.fetch_max(odd + 1, Ordering::SeqCst);
    }

    /// Total spans ever pushed.
    pub fn total(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Spans lost to ring wraparound: everything past capacity.
    pub fn dropped(&self) -> u64 {
        self.total().saturating_sub(self.slots.len() as u64)
    }

    /// Log one WARN when `dropped` has grown since the last call — one
    /// line per wraparound burst, not one per lost span, so an undersized
    /// ring (`--trace-buffer`) is visible without flooding the log.
    /// Returns the number of spans dropped since the last warning.
    pub fn warn_on_new_drops(&self, dropped: u64) -> u64 {
        let last = self.warned_dropped.fetch_max(dropped, Ordering::Relaxed);
        let new = dropped.saturating_sub(last);
        if new > 0 {
            crate::log_warn!(
                "trace",
                "span ring wrapped: {new} spans dropped since last export \
                 ({dropped} total; raise --trace-buffer past {} to keep more)",
                self.capacity()
            );
        }
        new
    }

    /// Copy out every readable span, oldest first.
    pub fn snapshot(&self) -> TraceSnapshot {
        let mut spans = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            let s1 = slot.seq.load(Ordering::SeqCst);
            if s1 == 0 || s1 % 2 == 1 {
                continue; // never written, or a write in progress
            }
            let words = [
                slot.words[0].load(Ordering::Relaxed),
                slot.words[1].load(Ordering::Relaxed),
                slot.words[2].load(Ordering::Relaxed),
                slot.words[3].load(Ordering::Relaxed),
            ];
            if slot.seq.load(Ordering::SeqCst) != s1 {
                continue; // overwritten while reading
            }
            spans.push(SpanRec::from_words(words));
        }
        spans.sort_by_key(|s| (s.start_us, s.trace_id, s.span_id));
        TraceSnapshot { spans, dropped: self.dropped(), total: self.total() }
    }
}

/// Per-request span recorder.  Lives on the executing thread's stack, so
/// `add` is a plain `Vec::push`; the finished timeline is flushed into the
/// shared ring in one pass.
pub struct TraceSession {
    trace_id: u64,
    t0: Instant,
    /// Offset of `t0` from the collector epoch (ring records are
    /// epoch-relative so one Chrome export holds many requests).
    base_us: u64,
    spans: Vec<SpanRec>,
    next_id: u16,
}

/// Parent id of top-level stage spans (the implicit `Request` root).
pub const ROOT_SPAN: u16 = 1;

impl TraceSession {
    pub fn start(col: &TraceCollector) -> TraceSession {
        TraceSession {
            trace_id: col.next_trace_id(),
            t0: Instant::now(),
            base_us: col.now_us(),
            spans: Vec::with_capacity(8),
            next_id: ROOT_SPAN, // root takes id 1; children start at 2
        }
    }

    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }

    /// Microseconds since the session root started.
    pub fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    /// Record one span (session-relative start) and return its id for use
    /// as a child's parent.
    pub fn add(&mut self, name: SpanName, parent: u16, start_us: u64, dur_us: u64) -> u16 {
        self.add_lane(name, parent, start_us, dur_us, 0)
    }

    /// [`TraceSession::add`] with an explicit lane (shard index etc).
    pub fn add_lane(
        &mut self,
        name: SpanName,
        parent: u16,
        start_us: u64,
        dur_us: u64,
        tid: u16,
    ) -> u16 {
        self.next_id = self.next_id.saturating_add(1);
        let id = self.next_id;
        self.spans.push(SpanRec {
            trace_id: self.trace_id,
            span_id: id,
            parent_id: parent,
            name: name as u16,
            tid,
            start_us,
            dur_us,
        });
        id
    }

    /// Close the root span, flush everything into the ring
    /// (epoch-relative), and return the session-relative timeline for
    /// embedding in the response.
    pub fn finish(mut self, col: &TraceCollector) -> Vec<SpanRec> {
        let root = SpanRec {
            trace_id: self.trace_id,
            span_id: ROOT_SPAN,
            parent_id: 0,
            name: SpanName::Request as u16,
            tid: 0,
            start_us: 0,
            dur_us: self.now_us(),
        };
        self.spans.insert(0, root);
        for span in &self.spans {
            let mut ring = *span;
            ring.start_us += self.base_us;
            col.push(ring);
        }
        self.spans
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(trace: u64, id: u16, start: u64) -> SpanRec {
        SpanRec {
            trace_id: trace,
            span_id: id,
            parent_id: if id == 1 { 0 } else { 1 },
            name: SpanName::Score as u16,
            tid: 3,
            start_us: start,
            dur_us: 7,
        }
    }

    #[test]
    fn pack_roundtrips_every_field() {
        let r = SpanRec {
            trace_id: u64::MAX,
            span_id: 0xBEEF,
            parent_id: 0x1234,
            name: SpanName::ConnWrite as u16,
            tid: 0xFFFF,
            start_us: 123_456_789,
            dur_us: 42,
        };
        let back = SpanRec::from_words([r.trace_id, r.pack_ids(), r.start_us, r.dur_us]);
        assert_eq!(back, r);
    }

    #[test]
    fn name_table_matches_discriminants() {
        for id in 0..SPAN_NAMES.len() as u16 {
            let n = SpanName::from_u16(id);
            assert_eq!(n as u16, id);
            assert_eq!(n.as_str(), SPAN_NAMES[id as usize]);
        }
    }

    #[test]
    fn ring_keeps_newest_and_counts_drops() {
        let col = TraceCollector::new(16);
        assert_eq!(col.capacity(), 16);
        for i in 0..40u64 {
            col.push(rec(i, 1, i));
        }
        let snap = col.snapshot();
        assert_eq!(snap.total, 40);
        assert_eq!(snap.dropped, 24, "40 pushed into 16 slots drops 24");
        assert_eq!(snap.spans.len(), 16);
        // exactly the newest 16 survive, in start order
        let traces: Vec<u64> = snap.spans.iter().map(|s| s.trace_id).collect();
        assert_eq!(traces, (24..40).collect::<Vec<u64>>());
    }

    #[test]
    fn snapshot_of_partial_ring_skips_unwritten_slots() {
        let col = TraceCollector::new(64);
        for i in 0..5u64 {
            col.push(rec(i, 1, 100 + i));
        }
        let snap = col.snapshot();
        assert_eq!(snap.spans.len(), 5);
        assert_eq!(snap.dropped, 0);
    }

    #[test]
    fn concurrent_pushes_never_yield_torn_records() {
        let col = std::sync::Arc::new(TraceCollector::new(32));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let col = std::sync::Arc::clone(&col);
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    // every field derives from the trace id, so a mixed
                    // record is detectable below
                    let v = t * 1000 + i;
                    col.push(SpanRec {
                        trace_id: v,
                        span_id: (v % 7) as u16 + 1,
                        parent_id: 0,
                        name: (v % SPAN_NAMES.len() as u64) as u16,
                        tid: (v % 13) as u16,
                        start_us: v * 3,
                        dur_us: v * 5,
                    });
                }
            }));
        }
        for _ in 0..50 {
            for s in col.snapshot().spans {
                assert_eq!(s.span_id as u64, s.trace_id % 7 + 1, "torn record {s:?}");
                assert_eq!(s.start_us, s.trace_id * 3, "torn record {s:?}");
                assert_eq!(s.dur_us, s.trace_id * 5, "torn record {s:?}");
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(col.total(), 2000);
        assert_eq!(col.dropped(), 2000 - 32);
    }

    #[test]
    fn session_builds_rooted_timeline_and_flushes_ring() {
        let col = TraceCollector::new(64);
        let mut s = TraceSession::start(&col);
        let prune = s.add(SpanName::Prune, ROOT_SPAN, 0, 10);
        let score = s.add(SpanName::Score, ROOT_SPAN, 10, 30);
        s.add_lane(SpanName::Shard, score, 12, 9, 2);
        let spans = s.finish(&col);
        assert_eq!(spans[0].name_str(), "request");
        assert_eq!(spans[0].span_id, ROOT_SPAN);
        assert_eq!(spans[0].parent_id, 0);
        assert!(spans[1..].iter().all(|s| s.trace_id == spans[0].trace_id));
        assert_eq!(spans[1].span_id, prune);
        assert_eq!(spans[1].parent_id, ROOT_SPAN);
        assert_eq!(spans[3].parent_id, score);
        assert_eq!(spans[3].tid, 2);
        // the ring got the same four spans
        assert_eq!(col.total(), 4);
        assert_eq!(col.snapshot().spans.len(), 4);
    }

    #[test]
    fn trace_ids_are_unique_per_session() {
        let col = TraceCollector::new(16);
        let a = TraceSession::start(&col).trace_id();
        let b = TraceSession::start(&col).trace_id();
        assert_ne!(a, b);
    }

    #[test]
    fn wraparound_warns_once_per_burst() {
        let col = TraceCollector::new(16);
        for i in 0..20u64 {
            col.push(rec(i, 1, i));
        }
        let d = col.dropped();
        assert_eq!(d, 4);
        assert_eq!(col.warn_on_new_drops(d), 4, "first export after a wrap warns");
        assert_eq!(col.warn_on_new_drops(d), 0, "steady ring stays quiet");
        for i in 0..3u64 {
            col.push(rec(100 + i, 1, i));
        }
        assert_eq!(col.warn_on_new_drops(col.dropped()), 3, "a new burst warns again");
    }

    #[test]
    fn enabled_flag_defaults_off() {
        let col = TraceCollector::new(16);
        assert!(!col.enabled());
        col.set_enabled(true);
        assert!(col.enabled());
    }
}
