//! Dependency-free mini HTTP/1.0 listener for Prometheus scrapes.
//!
//! Serves exactly one route — `GET /metrics` — with `Connection: close`
//! semantics; anything else is a 404.  One connection is handled at a
//! time: a scrape renders a few KiB of text, so serialization is cheaper
//! than threads, and a stuck scraper can't pile up sockets (reads are
//! capped and time-limited).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::core::EmdResult;

/// Most generous request head we accept before answering anyway: scrape
/// requests are one line plus a handful of headers.
const MAX_HEAD: usize = 4096;

/// Bind `addr` and serve `GET /metrics` forever on a background thread,
/// rendering the body through `render` per scrape.  Returns the bound
/// address (port 0 resolves an ephemeral port for tests) and the listener
/// thread handle.
pub fn spawn_metrics(
    addr: &str,
    render: Arc<dyn Fn() -> String + Send + Sync>,
) -> EmdResult<(SocketAddr, JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let handle = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { continue };
            let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
            let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
            let path = read_request_path(&mut stream);
            let response = match path.as_deref() {
                Some("/metrics") | Some("/metrics/") => ok_response(&render()),
                Some(_) => not_found(),
                None => bad_request(),
            };
            let _ = stream.write_all(response.as_bytes());
        }
    });
    Ok((local, handle))
}

/// Read up to the end of the request head (blank line) and return the
/// request-target of the first line, or `None` on malformed input.
fn read_request_path(stream: &mut impl Read) -> Option<String> {
    let mut head = Vec::with_capacity(512);
    let mut buf = [0u8; 512];
    let complete = |h: &[u8]| {
        // blank line ends the head; a bare-LF pair works too
        h.windows(4).any(|w| w == b"\r\n\r\n") || h.windows(2).any(|w| w == b"\n\n")
    };
    while head.len() < MAX_HEAD && !complete(&head) {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&buf[..n]),
            Err(_) => break,
        }
    }
    let first = head.split(|&b| b == b'\n').next()?;
    let line = std::str::from_utf8(first).ok()?.trim_end_matches('\r');
    let mut parts = line.split(' ');
    let method = parts.next()?;
    let target = parts.next()?;
    if method != "GET" {
        return None;
    }
    Some(target.to_string())
}

fn ok_response(body: &str) -> String {
    format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )
}

fn not_found() -> String {
    let body = "not found\n";
    format!(
        "HTTP/1.0 404 Not Found\r\nContent-Type: text/plain\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{}",
        body.len(),
        body
    )
}

fn bad_request() -> String {
    let body = "bad request\n";
    format!(
        "HTTP/1.0 400 Bad Request\r\nContent-Type: text/plain\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{}",
        body.len(),
        body
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpStream;

    fn scrape(addr: SocketAddr, request: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404s_everything_else() {
        let body = Arc::new(|| "emdpar_up 1\n".to_string());
        let (addr, _handle) = spawn_metrics("127.0.0.1:0", body).unwrap();
        let ok = scrape(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(ok.starts_with("HTTP/1.0 200 OK"), "{ok}");
        assert!(ok.contains("text/plain; version=0.0.4"));
        assert!(ok.ends_with("emdpar_up 1\n"));
        let missing = scrape(addr, "GET /other HTTP/1.1\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.0 404"), "{missing}");
        let bad = scrape(addr, "POST /metrics HTTP/1.1\r\n\r\n");
        assert!(bad.starts_with("HTTP/1.0 400"), "{bad}");
    }

    #[test]
    fn content_length_matches_body() {
        let body = Arc::new(|| "emdpar_queries_total 7\n".to_string());
        let (addr, _handle) = spawn_metrics("127.0.0.1:0", body).unwrap();
        let resp = scrape(addr, "GET /metrics HTTP/1.0\r\n\r\n");
        let (head, payload) = resp.split_once("\r\n\r\n").unwrap();
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(len, payload.len());
    }
}
