//! Dependency-free mini HTTP/1.0 listener for Prometheus scrapes and
//! health probes.
//!
//! Routes (all GET-only, `Connection: close` semantics):
//! * `/metrics` — the Prometheus text exposition, rendered per scrape,
//! * `/healthz` — process liveness: answers `200 ok` whenever the
//!   listener thread is alive,
//! * `/readyz` — serving readiness through an optional probe closure
//!   (corpus loaded + index trained + admission not saturated when wired
//!   by `emdpar serve`); `200 ready` or `503` with the reason.
//!
//! Anything else is a 404; a non-GET method is a 405; a malformed request
//! head is a 400.  One connection is handled at a time: a scrape renders
//! a few KiB of text, so serialization is cheaper than threads, and a
//! stuck scraper can't pile up sockets (reads are capped and
//! time-limited).  Write errors are swallowed per connection — a probe
//! that disconnects mid-response never takes the listener down.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::core::EmdResult;

/// Most generous request head we accept before answering anyway: scrape
/// requests are one line plus a handful of headers.
const MAX_HEAD: usize = 4096;

/// Readiness probe for `/readyz`: `Ok(())` is ready, `Err(why)` answers
/// 503 with the reason in the body.
pub type ReadyProbe = Arc<dyn Fn() -> Result<(), String> + Send + Sync>;

/// Bind `addr` and serve `GET /metrics` (+ `/healthz`) forever on a
/// background thread, rendering the body through `render` per scrape.
/// `/readyz` answers 404 until a probe is wired via [`spawn_listener`].
/// Returns the bound address (port 0 resolves an ephemeral port for
/// tests) and the listener thread handle.
pub fn spawn_metrics(
    addr: &str,
    render: Arc<dyn Fn() -> String + Send + Sync>,
) -> EmdResult<(SocketAddr, JoinHandle<()>)> {
    spawn_listener(addr, render, None)
}

/// [`spawn_metrics`] plus an optional `/readyz` probe.
pub fn spawn_listener(
    addr: &str,
    render: Arc<dyn Fn() -> String + Send + Sync>,
    ready: Option<ReadyProbe>,
) -> EmdResult<(SocketAddr, JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let handle = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { continue };
            let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
            let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
            let response = match read_request_line(&mut stream) {
                None => text_response("400 Bad Request", "bad request\n"),
                Some((method, target)) => route(&method, &target, &render, ready.as_ref()),
            };
            // a peer that vanished mid-write is its problem, not the
            // listener's
            let _ = stream.write_all(response.as_bytes());
        }
    });
    Ok((local, handle))
}

/// Dispatch one parsed request line.
fn route(
    method: &str,
    target: &str,
    render: &Arc<dyn Fn() -> String + Send + Sync>,
    ready: Option<&ReadyProbe>,
) -> String {
    if method != "GET" {
        return method_not_allowed();
    }
    match target.trim_end_matches('/') {
        "/metrics" => metrics_response(&render()),
        "/healthz" => text_response("200 OK", "ok\n"),
        "/readyz" => match ready {
            Some(probe) => match probe() {
                Ok(()) => text_response("200 OK", "ready\n"),
                Err(why) => text_response("503 Service Unavailable", &format!("{why}\n")),
            },
            None => text_response("404 Not Found", "not found\n"),
        },
        _ => text_response("404 Not Found", "not found\n"),
    }
}

/// Read up to the end of the request head (blank line) and return the
/// method and request-target of the first line, or `None` on malformed
/// input.
fn read_request_line(stream: &mut impl Read) -> Option<(String, String)> {
    let mut head = Vec::with_capacity(512);
    let mut buf = [0u8; 512];
    let complete = |h: &[u8]| {
        // blank line ends the head; a bare-LF pair works too
        h.windows(4).any(|w| w == b"\r\n\r\n") || h.windows(2).any(|w| w == b"\n\n")
    };
    while head.len() < MAX_HEAD && !complete(&head) {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&buf[..n]),
            Err(_) => break,
        }
    }
    let first = head.split(|&b| b == b'\n').next()?;
    let line = std::str::from_utf8(first).ok()?.trim_end_matches('\r');
    let mut parts = line.split(' ');
    let method = parts.next().filter(|m| !m.is_empty())?;
    let target = parts.next()?;
    Some((method.to_string(), target.to_string()))
}

/// The `/metrics` 200: Prometheus exposition content type.
fn metrics_response(body: &str) -> String {
    format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )
}

/// A plain-text response with the given status line suffix.
fn text_response(status: &str, body: &str) -> String {
    format!(
        "HTTP/1.0 {status}\r\nContent-Type: text/plain\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len(),
    )
}

fn method_not_allowed() -> String {
    let body = "method not allowed\n";
    format!(
        "HTTP/1.0 405 Method Not Allowed\r\nAllow: GET\r\nContent-Type: text/plain\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpStream;

    fn scrape(addr: SocketAddr, request: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn render() -> Arc<dyn Fn() -> String + Send + Sync> {
        Arc::new(|| "emdpar_up 1\n".to_string())
    }

    #[test]
    fn serves_metrics_and_404s_unknown_paths() {
        let (addr, _handle) = spawn_metrics("127.0.0.1:0", render()).unwrap();
        let ok = scrape(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(ok.starts_with("HTTP/1.0 200 OK"), "{ok}");
        assert!(ok.contains("text/plain; version=0.0.4"));
        assert!(ok.ends_with("emdpar_up 1\n"));
        let missing = scrape(addr, "GET /other HTTP/1.1\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.0 404"), "{missing}");
    }

    #[test]
    fn non_get_is_405_and_malformed_is_400() {
        let (addr, _handle) = spawn_metrics("127.0.0.1:0", render()).unwrap();
        let post = scrape(addr, "POST /metrics HTTP/1.1\r\n\r\n");
        assert!(post.starts_with("HTTP/1.0 405"), "{post}");
        assert!(post.contains("Allow: GET"), "{post}");
        let delete = scrape(addr, "DELETE /healthz HTTP/1.1\r\n\r\n");
        assert!(delete.starts_with("HTTP/1.0 405"), "{delete}");
        // no target at all: malformed, not a 404
        let bad = scrape(addr, "GARBAGE\r\n\r\n");
        assert!(bad.starts_with("HTTP/1.0 400"), "{bad}");
    }

    #[test]
    fn healthz_is_always_ok_and_readyz_follows_the_probe() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let ready = Arc::new(AtomicBool::new(false));
        let probe_ready = Arc::clone(&ready);
        let probe: ReadyProbe = Arc::new(move || {
            if probe_ready.load(Ordering::Relaxed) {
                Ok(())
            } else {
                Err("index not trained".to_string())
            }
        });
        let (addr, _handle) =
            spawn_listener("127.0.0.1:0", render(), Some(probe)).unwrap();
        let health = scrape(addr, "GET /healthz HTTP/1.0\r\n\r\n");
        assert!(health.starts_with("HTTP/1.0 200"), "{health}");
        assert!(health.ends_with("ok\n"));
        let not_ready = scrape(addr, "GET /readyz HTTP/1.0\r\n\r\n");
        assert!(not_ready.starts_with("HTTP/1.0 503"), "{not_ready}");
        assert!(not_ready.ends_with("index not trained\n"));
        ready.store(true, Ordering::Relaxed);
        let now_ready = scrape(addr, "GET /readyz HTTP/1.0\r\n\r\n");
        assert!(now_ready.starts_with("HTTP/1.0 200"), "{now_ready}");
        assert!(now_ready.ends_with("ready\n"));
    }

    #[test]
    fn readyz_without_a_probe_is_404() {
        let (addr, _handle) = spawn_metrics("127.0.0.1:0", render()).unwrap();
        let resp = scrape(addr, "GET /readyz HTTP/1.0\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.0 404"), "{resp}");
    }

    #[test]
    fn connection_dropped_mid_write_keeps_listener_alive() {
        // a big body forces the response past one socket buffer so the
        // peer's early close surfaces as a write error on the listener
        let big = Arc::new(|| "x".repeat(1 << 20));
        let (addr, _handle) = spawn_metrics("127.0.0.1:0", big).unwrap();
        for _ in 0..3 {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
            drop(s); // vanish without reading the response
        }
        // the listener must still answer a well-behaved client
        let resp = scrape(addr, "GET /healthz HTTP/1.0\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.0 200"), "{resp}");
    }

    #[test]
    fn content_length_matches_body() {
        let body = Arc::new(|| "emdpar_queries_total 7\n".to_string());
        let (addr, _handle) = spawn_metrics("127.0.0.1:0", body).unwrap();
        let resp = scrape(addr, "GET /metrics HTTP/1.0\r\n\r\n");
        let (head, payload) = resp.split_once("\r\n\r\n").unwrap();
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(len, payload.len());
    }
}
