//! Typed configuration system: JSON file + CLI overrides + validation.
//!
//! One [`Config`] drives the whole stack (dataset selection/generation,
//! engine parameters, coordinator/server behaviour, artifact runtime).  See
//! `examples/config.sample.json` for a template.  Method and metric strings
//! are parsed by the canonical implementations in [`crate::core`].

use std::path::{Path, PathBuf};

use crate::core::{CompressedKind, EmdError, EmdResult, Method, Metric, PqParams};
use crate::emd_ensure;
use crate::lc::KernelBackend;
use crate::util::cli::Parsed;
use crate::util::json::Json;

/// Which compute backend answers queries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Multithreaded CPU LC engine (default; fastest on this testbed).
    Native,
    /// AOT-compiled JAX/Pallas artifacts via PJRT.
    Artifact,
}

impl Backend {
    pub fn parse(s: &str) -> EmdResult<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Backend::Native),
            "artifact" | "pjrt" => Ok(Backend::Artifact),
            _ => Err(EmdError::parse("backend", s, "native | artifact")),
        }
    }
}

/// IVF pruning-index configuration: the coarse quantizer over document WCD
/// centroids that fronts the LC engines (see DESIGN.md "IVF pruning
/// index").  `None` in [`Config::index`] means exhaustive search.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IndexParams {
    /// Number of inverted lists (k-means cells).
    pub nlist: usize,
    /// Default lists probed per query; `>= nlist` means exhaustive.
    /// Clients can override per request (`"nprobe"` on the TCP protocol).
    pub nprobe: usize,
    /// Lloyd iterations when training.
    pub train_iters: usize,
    /// k-means++ seed (index training is deterministic per seed).
    pub seed: u64,
    /// Training caps `nlist` so the average list keeps at least this many
    /// documents.
    pub min_points_per_list: usize,
}

impl Default for IndexParams {
    fn default() -> Self {
        IndexParams { nlist: 64, nprobe: 8, train_iters: 10, seed: 42, min_points_per_list: 2 }
    }
}

/// Sharded live-corpus configuration: split the database into per-shard
/// engines (+ optional per-shard IVF indexes, trained shard-locally from
/// [`Config::index`]) that answer queries through a fan-out / top-ℓ-merge
/// route and accept appended documents at runtime (see DESIGN.md "Sharded
/// corpus & live ingestion").  `None` in [`Config::sharded`] keeps the
/// single monolithic corpus.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardParams {
    /// Shards to split the corpus into at build time.
    pub shards: usize,
    /// Append policy: a batch lands in the smallest shard until every shard
    /// holds at least this many documents, after which a fresh shard is
    /// opened.
    pub max_docs_per_shard: usize,
}

impl Default for ShardParams {
    fn default() -> Self {
        ShardParams { shards: 4, max_docs_per_shard: 1 << 20 }
    }
}

/// Serving-runtime configuration (the `serve` object / `--runtime` flags):
/// event-loop sizing, admission control, deadlines and framing limits.
/// These apply to the reactor server; the legacy thread server honors the
/// line cap and the default deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeParams {
    /// Event-loop threads multiplexing connections.
    pub reactors: usize,
    /// Searches allowed in flight before admission sheds with
    /// `{"ok":false,"error":"overloaded","retry_after_ms":...}`.
    pub max_inflight: usize,
    /// Default per-request deadline, milliseconds; 0 disables.  Requests
    /// can override per call with `"deadline_ms"`.
    pub deadline_ms: u64,
    /// Hard cap on one request line; longer lines answer a structured
    /// error and are discarded with bounded memory.
    pub max_line_bytes: usize,
    /// Close connections idle longer than this, milliseconds; 0 disables.
    pub idle_timeout_ms: u64,
    /// `retry_after_ms` hint attached to overload responses.
    pub retry_after_ms: u64,
    /// Slow-query log threshold in µs: any request slower than this is
    /// traced and logged at WARN with its per-stage span breakdown; 0
    /// disables.  The `EMDPAR_SLOW_QUERY_US` env var overrides at engine
    /// construction.
    pub slow_query_us: u64,
    /// Span ring capacity (records; ~40 bytes each, clamped to >= 16).
    pub trace_buffer: usize,
    /// Telemetry window duration, milliseconds: the sliding-window
    /// workload store aggregates per-`GroupKey` rates over a ring of
    /// windows this wide.  0 leaves the store disarmed (recording is a
    /// single branch and the serving path stays byte-identical).
    pub telemetry_window_ms: u64,
    /// Online recall auditing: deterministically sample 1 in this many
    /// served searches and replay them at full probe off the hot path.
    /// 0 disables auditing entirely.
    pub audit_sample: u64,
}

impl Default for ServeParams {
    fn default() -> Self {
        ServeParams {
            reactors: 2,
            max_inflight: 1024,
            deadline_ms: 0,
            max_line_bytes: 1 << 20,
            idle_timeout_ms: 0,
            retry_after_ms: 2,
            slow_query_us: 0,
            trace_buffer: 4096,
            telemetry_window_ms: 1000,
            audit_sample: 0,
        }
    }
}

/// Remote shard fan-out configuration (see DESIGN.md "Distributed
/// corpus").  `None` in [`Config::remote`] keeps the fan-out in-process;
/// set, the coordinator loads the topology manifest and dispatches its
/// `ShardFanout` stage to `emdpar node` replicas over TCP.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RemoteParams {
    /// Path to the topology manifest (shard id → replica endpoints).
    pub topology: String,
    /// Per-shard dispatch deadline, milliseconds.  A shard that produces
    /// no response by then (after retries and hedging) is dropped from the
    /// merge and the response is marked `"partial": true`.
    pub shard_timeout_ms: u64,
    /// Hedge delay, milliseconds: with more than one replica, a second
    /// attempt races the first after this long.  Once enough latency
    /// samples exist the observed per-shard p99 takes over (clamped to
    /// `[1ms, shard_timeout/2]`).  0 disables hedging.
    pub hedge_ms: u64,
    /// Pooled connections kept per replica.
    pub pool: usize,
    /// Retries after every in-flight attempt for a shard has failed
    /// (jittered exponential backoff; a node's `retry_after_ms` shed hint
    /// overrides the backoff base).
    pub retries: usize,
}

impl Default for RemoteParams {
    fn default() -> Self {
        RemoteParams {
            topology: String::new(),
            shard_timeout_ms: 1000,
            hedge_ms: 50,
            pool: 2,
            retries: 2,
        }
    }
}

/// Dataset source.
#[derive(Debug, Clone, PartialEq)]
pub enum DatasetSpec {
    /// Load a serialized `.bin` dataset.
    File(PathBuf),
    /// One contiguous shard slice of a serialized dataset: the rows the
    /// [`crate::coordinator::Router`] partition assigns to `shard` out of
    /// `of`.  This is what an `emdpar node` serves — the same rows, bit
    /// for bit, that the coordinator's in-process shard `shard` would
    /// hold when built with `of` shards.
    Slice { file: PathBuf, shard: usize, of: usize },
    /// Generate the synthetic MNIST substitute.
    SynthMnist { n: usize, background: f32, seed: u64 },
    /// Generate the synthetic 20News substitute.
    SynthText { n: usize, vocab: usize, dim: usize, seed: u64 },
}

/// Full stack configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub dataset: DatasetSpec,
    pub method: Method,
    pub metric: Metric,
    pub threads: usize,
    pub symmetric: bool,
    /// Phase-1 block size `B` for the batched multi-query kernel.
    pub batch_block: usize,
    pub backend: Backend,
    pub artifact_dir: PathBuf,
    pub artifact_profile: Option<String>,
    /// top-ℓ to return per query
    pub topl: usize,
    /// default cascade overfetch: stage 1 keeps `overfetch × ℓ` candidates
    /// when a request's `CascadeSpec` does not carry its own
    pub overfetch: usize,
    /// server bind address
    pub listen: String,
    /// dynamic batcher: max queries per batch
    pub max_batch: usize,
    /// dynamic batcher: max linger before dispatching a partial batch
    pub linger_ms: u64,
    /// number of database shards for the router
    pub shards: usize,
    /// IVF pruning index in front of the native engine (None = exhaustive).
    /// With [`Config::sharded`] set these become the *per-shard* index
    /// parameters (each shard trains its own coarse quantizer).
    pub index: Option<IndexParams>,
    /// sharded live corpus: per-shard engines + IVF, appendable at runtime
    /// (None = single monolithic corpus)
    pub sharded: Option<ShardParams>,
    /// remote shard fan-out: dispatch the shard stage to `emdpar node`
    /// replicas over TCP (None = in-process fan-out)
    pub remote: Option<RemoteParams>,
    /// serving-runtime knobs (reactor count, admission, deadlines, framing)
    pub serve: ServeParams,
    /// forced Phase-1 kernel backend (`None` = best the host supports;
    /// the `EMDPAR_KERNEL` env var still applies when unset).  Purely a
    /// speed knob — every backend is bit-identical.
    pub kernel: Option<KernelBackend>,
    /// compressed stage-1 residency: `"f16"` builds a half-precision copy
    /// of the embedding table (and IVF centroids) that candidate-scoring
    /// sweeps stream; the query planner restores exactness with an f32
    /// rerank.  Requires the native backend, L2 metric, unsharded corpus.
    pub compressed: CompressedKind,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            dataset: DatasetSpec::SynthMnist { n: 1000, background: 0.0, seed: 42 },
            method: Method::Act { k: 2 },
            metric: Metric::L2,
            threads: crate::util::threadpool::default_threads(),
            symmetric: true,
            batch_block: crate::lc::DEFAULT_BATCH_BLOCK,
            backend: Backend::Native,
            artifact_dir: PathBuf::from("artifacts"),
            artifact_profile: None,
            topl: 16,
            overfetch: 8,
            listen: "127.0.0.1:7878".to_string(),
            max_batch: 8,
            linger_ms: 2,
            shards: 4,
            index: None,
            sharded: None,
            remote: None,
            serve: ServeParams::default(),
            kernel: None,
            compressed: CompressedKind::Off,
        }
    }
}

impl Config {
    /// Load from a JSON file (all fields optional; defaults fill the rest).
    pub fn from_file(path: &Path) -> EmdResult<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| EmdError::io(format!("reading config {path:?}: {e}")))?;
        let json = Json::parse(&text)
            .map_err(|e| EmdError::json(format!("parsing config {path:?}: {e}")))?;
        Self::from_json(&json)
    }

    pub fn from_json(json: &Json) -> EmdResult<Config> {
        let mut cfg = Config::default();
        if let Some(d) = json.get("dataset") {
            cfg.dataset = parse_dataset(d)?;
        }
        if let Some(s) = json.get("method").and_then(Json::as_str) {
            cfg.method = Method::parse(s)?;
        }
        if let Some(s) = json.get("metric").and_then(Json::as_str) {
            cfg.metric = Metric::parse(s)
                .ok_or_else(|| EmdError::parse("metric", s, "l2 | sql2 | l1 | cosine"))?;
        }
        if let Some(x) = json.get("threads").and_then(Json::as_usize) {
            cfg.threads = x.max(1);
        }
        if let Some(b) = json.get("symmetric").and_then(Json::as_bool) {
            cfg.symmetric = b;
        }
        if let Some(x) = json.get("batch_block").and_then(Json::as_usize) {
            cfg.batch_block = x.max(1);
        }
        if let Some(s) = json.get("backend").and_then(Json::as_str) {
            cfg.backend = Backend::parse(s)?;
        }
        if let Some(s) = json.get("artifact_dir").and_then(Json::as_str) {
            cfg.artifact_dir = PathBuf::from(s);
        }
        if let Some(s) = json.get("artifact_profile").and_then(Json::as_str) {
            cfg.artifact_profile = Some(s.to_string());
        }
        if let Some(x) = json.get("topl").and_then(Json::as_usize) {
            cfg.topl = x.max(1);
        }
        if let Some(x) = json.get("overfetch").and_then(Json::as_usize) {
            cfg.overfetch = x.max(1);
        }
        if let Some(s) = json.get("listen").and_then(Json::as_str) {
            cfg.listen = s.to_string();
        }
        if let Some(x) = json.get("max_batch").and_then(Json::as_usize) {
            cfg.max_batch = x.max(1);
        }
        if let Some(x) = json.get("linger_ms").and_then(Json::as_usize) {
            cfg.linger_ms = x as u64;
        }
        if let Some(x) = json.get("shards").and_then(Json::as_usize) {
            cfg.shards = x.max(1);
        }
        if let Some(j) = json.get("index") {
            cfg.index = Some(parse_index(j)?);
        }
        if let Some(j) = json.get("shard") {
            cfg.sharded = Some(parse_shard(j)?);
        }
        if let Some(j) = json.get("remote") {
            cfg.remote = Some(parse_remote(j)?);
        }
        if let Some(j) = json.get("serve") {
            cfg.serve = parse_serve(j)?;
        }
        if let Some(s) = json.get("kernel").and_then(Json::as_str) {
            cfg.kernel = Some(parse_kernel(s)?);
        }
        if let Some(s) = json.get("compressed").and_then(Json::as_str) {
            cfg.compressed = parse_compressed(s)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply CLI overrides (`--method`, `--threads`, ...) from parsed args.
    pub fn apply_cli(&mut self, args: &Parsed) -> EmdResult<()> {
        if let Some(s) = args.opt_str("method") {
            if !s.is_empty() {
                self.method = Method::parse(s)?;
            }
        }
        if let Some(s) = args.opt_str("threads") {
            if !s.is_empty() {
                self.threads = s
                    .parse::<usize>()
                    .map_err(|_| EmdError::config(format!("bad --threads '{s}'")))?
                    .max(1);
            }
        }
        if let Some(s) = args.opt_str("backend") {
            if !s.is_empty() {
                self.backend = Backend::parse(s)?;
            }
        }
        if let Some(s) = args.opt_str("topl") {
            if !s.is_empty() {
                self.topl = s
                    .parse::<usize>()
                    .map_err(|_| EmdError::config(format!("bad --topl '{s}'")))?
                    .max(1);
            }
        }
        if let Some(s) = args.opt_str("dataset") {
            if !s.is_empty() {
                self.dataset = parse_dataset_str(s)?;
            }
        }
        // --nlist enables the index (or resizes a configured one); 0
        // disables it entirely (the serve_demo convention); --nprobe
        // adjusts the default probe width
        if let Some(s) = args.opt_str("nlist") {
            if !s.is_empty() {
                let nlist = s
                    .parse::<usize>()
                    .map_err(|_| EmdError::config(format!("bad --nlist '{s}'")))?;
                if nlist == 0 {
                    self.index = None;
                } else {
                    let mut p = self.index.unwrap_or_default();
                    p.nlist = nlist;
                    self.index = Some(p);
                }
            }
        }
        if let Some(s) = args.opt_str("kernel") {
            if !s.is_empty() {
                self.kernel = Some(parse_kernel(s)?);
            }
        }
        if let Some(s) = args.opt_str("compressed") {
            if !s.is_empty() {
                self.compressed = parse_compressed(s)?;
            }
        }
        // --topology enables remote fan-out (or repoints a configured
        // one); the remaining remote flags only tune an already-enabled
        // fan-out, mirroring the --nlist / --nprobe convention
        if let Some(s) = args.opt_str("topology") {
            if !s.is_empty() {
                let mut p = self.remote.clone().unwrap_or_default();
                p.topology = s.to_string();
                self.remote = Some(p);
            }
        }
        let parse_u64 = |flag: &str, s: &str| {
            s.parse::<u64>().map_err(|_| EmdError::config(format!("bad --{flag} '{s}'")))
        };
        let need_remote = |flag: &str| {
            EmdError::config(format!(
                "--{flag} requires remote fan-out (pass --topology or set 'remote' \
                 in the config file)"
            ))
        };
        if let Some(s) = args.opt_str("shard-timeout-ms") {
            if !s.is_empty() {
                let v = parse_u64("shard-timeout-ms", s)?.max(1);
                self.remote
                    .as_mut()
                    .ok_or_else(|| need_remote("shard-timeout-ms"))?
                    .shard_timeout_ms = v;
            }
        }
        if let Some(s) = args.opt_str("hedge-ms") {
            if !s.is_empty() {
                let v = parse_u64("hedge-ms", s)?;
                self.remote.as_mut().ok_or_else(|| need_remote("hedge-ms"))?.hedge_ms = v;
            }
        }
        if let Some(s) = args.opt_str("remote-pool") {
            if !s.is_empty() {
                let v = (parse_u64("remote-pool", s)? as usize).max(1);
                self.remote.as_mut().ok_or_else(|| need_remote("remote-pool"))?.pool = v;
            }
        }
        if let Some(s) = args.opt_str("remote-retries") {
            if !s.is_empty() {
                let v = parse_u64("remote-retries", s)? as usize;
                self.remote.as_mut().ok_or_else(|| need_remote("remote-retries"))?.retries = v;
            }
        }
        if let Some(s) = args.opt_str("nprobe") {
            if !s.is_empty() {
                let nprobe = s
                    .parse::<usize>()
                    .map_err(|_| EmdError::config(format!("bad --nprobe '{s}'")))?
                    .max(1);
                // only tunes an index that is already configured — silently
                // enabling approximate search from a probe-width flag alone
                // would change result semantics the user never opted into
                match &mut self.index {
                    Some(p) => p.nprobe = nprobe,
                    None => {
                        return Err(EmdError::config(
                            "--nprobe requires an IVF index (pass --nlist or set \
                             'index' in the config file)",
                        ))
                    }
                }
            }
        }
        self.validate()
    }

    pub fn validate(&self) -> EmdResult<()> {
        emd_ensure!(self.threads >= 1, config, "threads must be >= 1");
        emd_ensure!(self.overfetch >= 1, config, "overfetch must be >= 1");
        emd_ensure!(self.batch_block >= 1, config, "batch_block must be >= 1");
        emd_ensure!(self.max_batch >= 1, config, "max_batch must be >= 1");
        emd_ensure!(self.shards >= 1, config, "shards must be >= 1");
        if let Method::Act { k } = self.method {
            emd_ensure!(k >= 1 && k <= 64, config, "ACT k must be in [1, 64], got {k}");
        }
        if let Some(ix) = &self.index {
            emd_ensure!(ix.nlist >= 1, config, "index nlist must be >= 1");
            emd_ensure!(ix.nprobe >= 1, config, "index nprobe must be >= 1");
            emd_ensure!(ix.train_iters >= 1, config, "index train_iters must be >= 1");
            emd_ensure!(
                ix.min_points_per_list >= 1,
                config,
                "index min_points_per_list must be >= 1"
            );
        }
        if let Some(sp) = &self.sharded {
            emd_ensure!(sp.shards >= 1, config, "shard count must be >= 1");
            emd_ensure!(
                sp.max_docs_per_shard >= 1,
                config,
                "shard max_docs_per_shard must be >= 1"
            );
            emd_ensure!(
                self.backend == Backend::Native,
                config,
                "the sharded live corpus requires the native backend"
            );
        }
        if let Some(kb) = self.kernel {
            emd_ensure!(
                kb.is_supported(),
                config,
                "kernel backend '{}' forced but this host cannot execute it",
                kb.name()
            );
        }
        if self.compressed != CompressedKind::Off {
            emd_ensure!(
                self.backend == Backend::Native,
                config,
                "compressed stage-1 residency requires the native backend"
            );
            emd_ensure!(
                self.metric == Metric::L2,
                config,
                "compressed stage-1 residency is implemented for the L2 metric only"
            );
            emd_ensure!(
                self.sharded.is_none(),
                config,
                "compressed stage-1 residency is not available on the sharded corpus"
            );
        }
        if let DatasetSpec::Slice { shard, of, .. } = &self.dataset {
            emd_ensure!(*of >= 1, config, "dataset slice shard count must be >= 1");
            emd_ensure!(
                shard < of,
                config,
                "dataset slice shard {shard} out of range: must be < {of}"
            );
        }
        if let Some(rp) = &self.remote {
            emd_ensure!(
                !rp.topology.trim().is_empty(),
                config,
                "remote topology path must not be empty"
            );
            emd_ensure!(rp.shard_timeout_ms >= 1, config, "remote shard_timeout_ms must be >= 1");
            emd_ensure!(rp.pool >= 1, config, "remote pool must be >= 1");
            emd_ensure!(
                self.sharded.is_some(),
                config,
                "remote fan-out requires the sharded corpus (set 'shard' in the config)"
            );
            emd_ensure!(
                self.backend == Backend::Native,
                config,
                "remote fan-out requires the native backend"
            );
        }
        emd_ensure!(self.serve.reactors >= 1, config, "serve reactors must be >= 1");
        emd_ensure!(self.serve.max_inflight >= 1, config, "serve max_inflight must be >= 1");
        emd_ensure!(
            self.serve.max_line_bytes >= 256,
            config,
            "serve max_line_bytes must be >= 256"
        );
        emd_ensure!(
            self.serve.trace_buffer >= 16,
            config,
            "serve trace_buffer must be >= 16 span records"
        );
        emd_ensure!(
            self.serve.audit_sample == 0 || self.serve.telemetry_window_ms > 0,
            config,
            "serve audit_sample requires telemetry (telemetry_window_ms > 0) to \
             publish its recall estimates"
        );
        Ok(())
    }

    /// Materialize the dataset this config describes.
    pub fn load_dataset(&self) -> EmdResult<crate::core::Dataset> {
        Ok(match &self.dataset {
            DatasetSpec::File(path) => crate::data::load(path)?,
            DatasetSpec::Slice { file, shard, of } => {
                let full = crate::data::load(file)?;
                let router = crate::coordinator::Router::new(full.len(), *of);
                emd_ensure!(
                    *shard < router.num_shards(),
                    config,
                    "slice {shard}/{of}: dataset {file:?} has {} rows, only {} shards",
                    full.len(),
                    router.num_shards()
                );
                let range = router.shard(*shard);
                let globals: Vec<u32> = (range.start as u32..range.end as u32).collect();
                let name = format!("{}@{shard}/{of}", full.name);
                crate::shard::corpus::gather_rows(&full, &globals, name)
            }
            DatasetSpec::SynthMnist { n, background, seed } => {
                crate::data::generate_mnist(&crate::data::MnistConfig {
                    n: *n,
                    background: *background,
                    seed: *seed,
                    ..Default::default()
                })
            }
            DatasetSpec::SynthText { n, vocab, dim, seed } => {
                crate::data::generate_text(&crate::data::TextConfig {
                    n: *n,
                    vocab: *vocab,
                    dim: *dim,
                    seed: *seed,
                    ..Default::default()
                })
            }
        })
    }
}

fn parse_kernel(s: &str) -> EmdResult<KernelBackend> {
    KernelBackend::parse(s).ok_or_else(|| EmdError::parse("kernel", s, "scalar | avx2 | avx512"))
}

fn parse_compressed(s: &str) -> EmdResult<CompressedKind> {
    match s.to_ascii_lowercase().as_str() {
        "none" | "off" | "f32" => Ok(CompressedKind::Off),
        "f16" | "fp16" | "half" => Ok(CompressedKind::F16),
        // PQ is declared groundwork: surface the canonical explanation
        // instead of a bare parse error
        "pq" => Err(PqParams::default()
            .validate()
            .expect_err("PQ residency is groundwork and must not validate")),
        _ => Err(EmdError::parse("compressed", s, "none | f16")),
    }
}

fn parse_index(j: &Json) -> EmdResult<IndexParams> {
    let mut p = IndexParams::default();
    if let Some(x) = j.get("nlist").and_then(Json::as_usize) {
        p.nlist = x;
    }
    if let Some(x) = j.get("nprobe").and_then(Json::as_usize) {
        p.nprobe = x;
    }
    if let Some(x) = j.get("train_iters").and_then(Json::as_usize) {
        p.train_iters = x;
    }
    if let Some(x) = j.get("seed").and_then(Json::as_usize) {
        p.seed = x as u64;
    }
    if let Some(x) = j.get("min_points_per_list").and_then(Json::as_usize) {
        p.min_points_per_list = x;
    }
    Ok(p)
}

fn parse_shard(j: &Json) -> EmdResult<ShardParams> {
    let mut p = ShardParams::default();
    if let Some(x) = j.get("shards").and_then(Json::as_usize) {
        p.shards = x;
    }
    if let Some(x) = j.get("max_docs_per_shard").and_then(Json::as_usize) {
        p.max_docs_per_shard = x;
    }
    Ok(p)
}

fn parse_remote(j: &Json) -> EmdResult<RemoteParams> {
    let mut p = RemoteParams::default();
    if let Some(s) = j.get("topology").and_then(Json::as_str) {
        p.topology = s.to_string();
    }
    if let Some(x) = j.get("shard_timeout_ms").and_then(Json::as_usize) {
        p.shard_timeout_ms = x as u64;
    }
    if let Some(x) = j.get("hedge_ms").and_then(Json::as_usize) {
        p.hedge_ms = x as u64;
    }
    if let Some(x) = j.get("pool").and_then(Json::as_usize) {
        p.pool = x;
    }
    if let Some(x) = j.get("retries").and_then(Json::as_usize) {
        p.retries = x;
    }
    Ok(p)
}

fn parse_serve(j: &Json) -> EmdResult<ServeParams> {
    let mut p = ServeParams::default();
    if let Some(x) = j.get("reactors").and_then(Json::as_usize) {
        p.reactors = x;
    }
    if let Some(x) = j.get("max_inflight").and_then(Json::as_usize) {
        p.max_inflight = x;
    }
    if let Some(x) = j.get("deadline_ms").and_then(Json::as_usize) {
        p.deadline_ms = x as u64;
    }
    if let Some(x) = j.get("max_line_bytes").and_then(Json::as_usize) {
        p.max_line_bytes = x;
    }
    if let Some(x) = j.get("idle_timeout_ms").and_then(Json::as_usize) {
        p.idle_timeout_ms = x as u64;
    }
    if let Some(x) = j.get("retry_after_ms").and_then(Json::as_usize) {
        p.retry_after_ms = x as u64;
    }
    if let Some(x) = j.get("slow_query_us").and_then(Json::as_usize) {
        p.slow_query_us = x as u64;
    }
    if let Some(x) = j.get("trace_buffer").and_then(Json::as_usize) {
        p.trace_buffer = x;
    }
    if let Some(x) = j.get("telemetry_window_ms").and_then(Json::as_usize) {
        p.telemetry_window_ms = x as u64;
    }
    if let Some(x) = j.get("audit_sample").and_then(Json::as_usize) {
        p.audit_sample = x as u64;
    }
    Ok(p)
}

fn parse_dataset(j: &Json) -> EmdResult<DatasetSpec> {
    if let Some(s) = j.as_str() {
        return parse_dataset_str(s);
    }
    let kind = j
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| EmdError::config("dataset object needs 'kind'"))?;
    let n = j.get("n").and_then(Json::as_usize).unwrap_or(1000);
    let seed = j.get("seed").and_then(Json::as_usize).unwrap_or(42) as u64;
    Ok(match kind {
        "file" => DatasetSpec::File(PathBuf::from(
            j.get("path")
                .and_then(Json::as_str)
                .ok_or_else(|| EmdError::config("file dataset needs 'path'"))?,
        )),
        "slice" => DatasetSpec::Slice {
            file: PathBuf::from(
                j.get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| EmdError::config("slice dataset needs 'path'"))?,
            ),
            shard: j
                .get("shard")
                .and_then(Json::as_usize)
                .ok_or_else(|| EmdError::config("slice dataset needs 'shard'"))?,
            of: j
                .get("of")
                .and_then(Json::as_usize)
                .ok_or_else(|| EmdError::config("slice dataset needs 'of'"))?,
        },
        "synth-mnist" => DatasetSpec::SynthMnist {
            n,
            background: j.get("background").and_then(Json::as_f64).unwrap_or(0.0) as f32,
            seed,
        },
        "synth-text" => DatasetSpec::SynthText {
            n,
            vocab: j.get("vocab").and_then(Json::as_usize).unwrap_or(8000),
            dim: j.get("dim").and_then(Json::as_usize).unwrap_or(64),
            seed,
        },
        other => {
            return Err(EmdError::parse(
                "dataset kind",
                other,
                "file | slice | synth-mnist | synth-text",
            ))
        }
    })
}

/// CLI shorthand: `path.bin` | `path.bin@<shard>/<of>` | `synth-mnist:<n>`
/// | `synth-text:<n>`.
fn parse_dataset_str(s: &str) -> EmdResult<DatasetSpec> {
    if let Some(rest) = s.strip_prefix("synth-mnist") {
        let n = rest
            .strip_prefix(':')
            .map(|r| r.parse())
            .transpose()
            .map_err(|_| EmdError::config("bad synth-mnist size"))?
            .unwrap_or(1000);
        return Ok(DatasetSpec::SynthMnist { n, background: 0.0, seed: 42 });
    }
    if let Some(rest) = s.strip_prefix("synth-text") {
        let n = rest
            .strip_prefix(':')
            .map(|r| r.parse())
            .transpose()
            .map_err(|_| EmdError::config("bad synth-text size"))?
            .unwrap_or(1000);
        return Ok(DatasetSpec::SynthText { n, vocab: 8000, dim: 64, seed: 1234 });
    }
    // `path@s/of` picks one Router shard slice of a serialized dataset
    if let Some((path, rest)) = s.rsplit_once('@') {
        if let Some((shard, of)) = rest.split_once('/') {
            if let (Ok(shard), Ok(of)) = (shard.parse::<usize>(), of.parse::<usize>()) {
                return Ok(DatasetSpec::Slice { file: PathBuf::from(path), shard, of });
            }
        }
    }
    Ok(DatasetSpec::File(PathBuf::from(s)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_fields() {
        let j = Json::parse(
            r#"{"method": "act-3", "threads": 2, "backend": "artifact",
                "dataset": {"kind": "synth-text", "n": 50, "vocab": 100, "dim": 8},
                "topl": 5, "symmetric": false}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.method, Method::Act { k: 4 });
        assert_eq!(cfg.threads, 2);
        assert_eq!(cfg.backend, Backend::Artifact);
        assert_eq!(cfg.topl, 5);
        assert!(!cfg.symmetric);
        assert_eq!(cfg.dataset, DatasetSpec::SynthText { n: 50, vocab: 100, dim: 8, seed: 42 });
    }

    #[test]
    fn bad_method_rejected() {
        let j = Json::parse(r#"{"method": "magic"}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
    }

    #[test]
    fn sinkhorn_and_exact_are_configurable() {
        // the comparators flow through the same canonical parser
        for (s, want) in [("sinkhorn", Method::Sinkhorn), ("emd", Method::Exact)] {
            let j = Json::parse(&format!(r#"{{"method": "{s}"}}"#)).unwrap();
            assert_eq!(Config::from_json(&j).unwrap().method, want);
        }
    }

    #[test]
    fn dataset_shorthand() {
        assert_eq!(
            parse_dataset_str("synth-mnist:200").unwrap(),
            DatasetSpec::SynthMnist { n: 200, background: 0.0, seed: 42 }
        );
        assert!(matches!(parse_dataset_str("foo.bin").unwrap(), DatasetSpec::File(_)));
    }

    #[test]
    fn index_params_from_json_and_validation() {
        let j = Json::parse(
            r#"{"index": {"nlist": 32, "nprobe": 4, "train_iters": 6, "seed": 7,
                "min_points_per_list": 3}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(
            cfg.index,
            Some(IndexParams {
                nlist: 32,
                nprobe: 4,
                train_iters: 6,
                seed: 7,
                min_points_per_list: 3
            })
        );
        // partial objects fill from defaults
        let j = Json::parse(r#"{"index": {"nlist": 16}}"#).unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.index.unwrap().nlist, 16);
        assert_eq!(cfg.index.unwrap().nprobe, IndexParams::default().nprobe);
        // zero nprobe is rejected
        let j = Json::parse(r#"{"index": {"nprobe": 0}}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
        // no index object -> exhaustive
        assert_eq!(Config::default().index, None);
    }

    #[test]
    fn nprobe_flag_requires_an_index() {
        use crate::util::cli::CommandSpec;
        let spec = CommandSpec::new("t", "")
            .opt("nlist", "", "")
            .opt("nprobe", "", "");
        let parse = |args: &[&str]| {
            spec.parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
        };
        // --nprobe alone must not silently enable approximate search
        let mut cfg = Config::default();
        assert!(cfg.apply_cli(&parse(&["--nprobe", "4"])).is_err());
        // --nlist 0 disables a configured index
        let mut cfg = Config { index: Some(IndexParams::default()), ..Default::default() };
        cfg.apply_cli(&parse(&["--nlist", "0"])).unwrap();
        assert_eq!(cfg.index, None);
        // --nlist enables the index; --nprobe then tunes it
        let mut cfg = Config::default();
        cfg.apply_cli(&parse(&["--nlist", "32", "--nprobe", "4"])).unwrap();
        let p = cfg.index.unwrap();
        assert_eq!((p.nlist, p.nprobe), (32, 4));
        // a config-file index is tunable from the flag too
        let mut cfg = Config { index: Some(IndexParams::default()), ..Default::default() };
        cfg.apply_cli(&parse(&["--nprobe", "3"])).unwrap();
        assert_eq!(cfg.index.unwrap().nprobe, 3);
    }

    #[test]
    fn shard_params_from_json_and_validation() {
        let j = Json::parse(r#"{"shard": {"shards": 8, "max_docs_per_shard": 5000}}"#).unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.sharded, Some(ShardParams { shards: 8, max_docs_per_shard: 5000 }));
        // partial objects fill from defaults
        let j = Json::parse(r#"{"shard": {"shards": 2}}"#).unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.sharded.unwrap().shards, 2);
        assert_eq!(
            cfg.sharded.unwrap().max_docs_per_shard,
            ShardParams::default().max_docs_per_shard
        );
        // zero shards rejected
        let j = Json::parse(r#"{"shard": {"shards": 0}}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
        // the sharded corpus is a native-backend feature
        let j = Json::parse(r#"{"shard": {"shards": 2}, "backend": "artifact"}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
        // no shard object -> monolithic corpus
        assert_eq!(Config::default().sharded, None);
    }

    #[test]
    fn remote_params_from_json_and_validation() {
        let j = Json::parse(
            r#"{"shard": {"shards": 2},
                "remote": {"topology": "topo.json", "shard_timeout_ms": 250,
                           "hedge_ms": 10, "pool": 3, "retries": 1}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(
            cfg.remote,
            Some(RemoteParams {
                topology: "topo.json".into(),
                shard_timeout_ms: 250,
                hedge_ms: 10,
                pool: 3,
                retries: 1,
            })
        );
        // partial objects fill from defaults
        let j = Json::parse(r#"{"shard": {}, "remote": {"topology": "t.json"}}"#).unwrap();
        let cfg = Config::from_json(&j).unwrap();
        let p = cfg.remote.unwrap();
        assert_eq!(p.shard_timeout_ms, RemoteParams::default().shard_timeout_ms);
        assert_eq!(p.hedge_ms, 50);
        assert_eq!((p.pool, p.retries), (2, 2));
        // degenerate or inconsistent configurations rejected
        for bad in [
            // remote without the sharded corpus
            r#"{"remote": {"topology": "t.json"}}"#,
            // empty topology path
            r#"{"shard": {}, "remote": {"topology": "  "}}"#,
            r#"{"shard": {}, "remote": {"topology": "t.json", "pool": 0}}"#,
            r#"{"shard": {}, "remote": {"topology": "t.json", "shard_timeout_ms": 0}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Config::from_json(&j).is_err(), "{bad}");
        }
        // no remote object -> in-process fan-out
        assert_eq!(Config::default().remote, None);
    }

    #[test]
    fn remote_flags_require_a_topology() {
        use crate::util::cli::CommandSpec;
        let spec = CommandSpec::new("t", "")
            .opt("topology", "", "")
            .opt("shard-timeout-ms", "", "")
            .opt("hedge-ms", "", "");
        let parse = |args: &[&str]| {
            spec.parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
        };
        // tuning flags alone must not silently enable remote fan-out
        let mut cfg = Config { sharded: Some(ShardParams::default()), ..Default::default() };
        assert!(cfg.apply_cli(&parse(&["--hedge-ms", "5"])).is_err());
        // --topology enables it; the tuning flags then apply
        let mut cfg = Config { sharded: Some(ShardParams::default()), ..Default::default() };
        cfg.apply_cli(&parse(&[
            "--topology",
            "topo.json",
            "--shard-timeout-ms",
            "300",
            "--hedge-ms",
            "0",
        ]))
        .unwrap();
        let p = cfg.remote.unwrap();
        assert_eq!(p.topology, "topo.json");
        assert_eq!((p.shard_timeout_ms, p.hedge_ms), (300, 0));
        // remote fan-out still requires the sharded corpus
        let mut cfg = Config::default();
        assert!(cfg.apply_cli(&parse(&["--topology", "topo.json"])).is_err());
    }

    #[test]
    fn slice_dataset_parses_and_validates() {
        // CLI shorthand
        assert_eq!(
            parse_dataset_str("corpus.bin@1/4").unwrap(),
            DatasetSpec::Slice { file: PathBuf::from("corpus.bin"), shard: 1, of: 4 }
        );
        // a plain path with no slice suffix stays a file spec
        assert!(matches!(parse_dataset_str("we@ird.bin").unwrap(), DatasetSpec::File(_)));
        // JSON object form
        let j = Json::parse(
            r#"{"dataset": {"kind": "slice", "path": "corpus.bin", "shard": 0, "of": 2}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(
            cfg.dataset,
            DatasetSpec::Slice { file: PathBuf::from("corpus.bin"), shard: 0, of: 2 }
        );
        // shard index must be in range
        let bad = Config {
            dataset: DatasetSpec::Slice { file: PathBuf::from("x.bin"), shard: 2, of: 2 },
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn serve_params_from_json_and_validation() {
        let j = Json::parse(
            r#"{"serve": {"reactors": 4, "max_inflight": 64, "deadline_ms": 250,
                "max_line_bytes": 4096, "idle_timeout_ms": 30000, "retry_after_ms": 5,
                "slow_query_us": 250000, "trace_buffer": 1024,
                "telemetry_window_ms": 500, "audit_sample": 64}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(
            cfg.serve,
            ServeParams {
                reactors: 4,
                max_inflight: 64,
                deadline_ms: 250,
                max_line_bytes: 4096,
                idle_timeout_ms: 30000,
                retry_after_ms: 5,
                slow_query_us: 250_000,
                trace_buffer: 1024,
                telemetry_window_ms: 500,
                audit_sample: 64,
            }
        );
        // partial objects fill from defaults
        let j = Json::parse(r#"{"serve": {"reactors": 1}}"#).unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.serve.reactors, 1);
        assert_eq!(cfg.serve.max_inflight, ServeParams::default().max_inflight);
        assert_eq!(cfg.serve.slow_query_us, 0, "slow-query log defaults off");
        assert_eq!(cfg.serve.trace_buffer, ServeParams::default().trace_buffer);
        assert_eq!(cfg.serve.telemetry_window_ms, 1000, "telemetry defaults to 1 s windows");
        assert_eq!(cfg.serve.audit_sample, 0, "recall auditing defaults off");
        // degenerate values rejected
        for bad in [
            r#"{"serve": {"reactors": 0}}"#,
            r#"{"serve": {"max_inflight": 0}}"#,
            r#"{"serve": {"max_line_bytes": 16}}"#,
            r#"{"serve": {"trace_buffer": 4}}"#,
            // auditing needs the telemetry surface to publish through
            r#"{"serve": {"telemetry_window_ms": 0, "audit_sample": 64}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Config::from_json(&j).is_err(), "{bad}");
        }
        // absent -> defaults
        assert_eq!(Config::default().serve, ServeParams::default());
    }

    #[test]
    fn kernel_and_compressed_knobs_parse_and_validate() {
        // scalar is supported everywhere; f16 residency rides the defaults
        let j = Json::parse(r#"{"kernel": "scalar", "compressed": "f16"}"#).unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.kernel, Some(KernelBackend::Scalar));
        assert_eq!(cfg.compressed, CompressedKind::F16);
        // unset -> auto-detect / exact
        assert_eq!(Config::default().kernel, None);
        assert_eq!(Config::default().compressed, CompressedKind::Off);
        // unknown names are rejected
        assert!(Config::from_json(&Json::parse(r#"{"kernel": "neon"}"#).unwrap()).is_err());
        assert!(
            Config::from_json(&Json::parse(r#"{"compressed": "int4"}"#).unwrap()).is_err()
        );
        // PQ is groundwork: rejected with the canonical message
        let err =
            Config::from_json(&Json::parse(r#"{"compressed": "pq"}"#).unwrap()).unwrap_err();
        assert!(err.to_string().contains("groundwork"), "{err}");
        // the compressed tier needs the native backend and an unsharded corpus
        let bad = Config {
            compressed: CompressedKind::F16,
            backend: Backend::Artifact,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = Config {
            compressed: CompressedKind::F16,
            sharded: Some(ShardParams::default()),
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        // CLI overrides flow through apply_cli
        use crate::util::cli::CommandSpec;
        let spec = CommandSpec::new("t", "")
            .opt("kernel", "", "")
            .opt("compressed", "", "");
        let parsed = spec
            .parse(
                &["--kernel", "scalar", "--compressed", "f16"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect::<Vec<_>>(),
            )
            .unwrap();
        let mut cfg = Config::default();
        cfg.apply_cli(&parsed).unwrap();
        assert_eq!(cfg.kernel, Some(KernelBackend::Scalar));
        assert_eq!(cfg.compressed, CompressedKind::F16);
    }

    #[test]
    fn load_dataset_synth() {
        let cfg = Config {
            dataset: DatasetSpec::SynthText { n: 20, vocab: 100, dim: 8, seed: 1 },
            ..Default::default()
        };
        let ds = cfg.load_dataset().unwrap();
        assert_eq!(ds.len(), 20);
    }
}
