//! Fluent construction of the engine stack: dataset → params → backend →
//! build.
//!
//! [`EngineBuilder`] is the one place that turns configuration into running
//! engines, whichever layer you need:
//!
//! * [`EngineBuilder::build_lc`] — the batched CPU [`LcEngine`] (library /
//!   evaluation use);
//! * [`EngineBuilder::build_search`] — the coordinator-owned
//!   [`SearchEngine`] (serving use, optionally PJRT-backed);
//! * [`EngineBuilder::registry`] — the matching [`MethodRegistry`] for
//!   per-pair trait objects.
//!
//! ```no_run
//! use emdpar::prelude::*;
//!
//! let engine = EngineBuilder::new()
//!     .dataset_spec(DatasetSpec::SynthMnist { n: 1000, background: 0.0, seed: 42 })
//!     .method(Method::Act { k: 2 })     // request default
//!     .topl(16)                         // request default
//!     .overfetch(8)                     // request default (cascade stage 1)
//!     .threads(8)
//!     .build_search()?;
//! // one composable entry point: defaults above fill any unset field
//! let request = SearchRequest::query(engine.dataset().histogram(0)).topl(5);
//! let response = engine.execute(&request)?;
//! assert_eq!(response.results[0].hits.len(), 5);
//! # Ok::<(), EmdError>(())
//! ```

use std::sync::Arc;

use crate::config::{
    Backend, Config, DatasetSpec, IndexParams, RemoteParams, ServeParams, ShardParams,
};
use crate::core::{CompressedKind, Dataset, EmdResult, Method, MethodRegistry, Metric};
use crate::coordinator::SearchEngine;
use crate::lc::{EngineParams, KernelBackend, LcEngine};

/// Builder for the engine stack.  Starts from [`Config::default`] (or a
/// loaded config via [`EngineBuilder::from_config`]); every setter overrides
/// one field; `build_*` materializes the dataset and constructs the engine.
#[derive(Debug, Clone)]
pub struct EngineBuilder {
    config: Config,
    dataset: Option<Arc<Dataset>>,
}

impl EngineBuilder {
    pub fn new() -> EngineBuilder {
        EngineBuilder { config: Config::default(), dataset: None }
    }

    /// Start from an existing config (e.g. loaded from JSON + CLI flags).
    pub fn from_config(config: Config) -> EngineBuilder {
        EngineBuilder { config, dataset: None }
    }

    /// Use an already-materialized dataset (shared, not copied).
    pub fn dataset(mut self, dataset: Arc<Dataset>) -> EngineBuilder {
        self.dataset = Some(dataset);
        self
    }

    /// Describe the dataset to load/generate at build time.
    pub fn dataset_spec(mut self, spec: DatasetSpec) -> EngineBuilder {
        self.config.dataset = spec;
        self.dataset = None;
        self
    }

    pub fn method(mut self, method: Method) -> EngineBuilder {
        self.config.method = method;
        self
    }

    pub fn metric(mut self, metric: Metric) -> EngineBuilder {
        self.config.metric = metric;
        self
    }

    pub fn threads(mut self, threads: usize) -> EngineBuilder {
        self.config.threads = threads.max(1);
        self
    }

    pub fn symmetric(mut self, symmetric: bool) -> EngineBuilder {
        self.config.symmetric = symmetric;
        self
    }

    /// Phase-1 block size `B` for the batched multi-query kernel.
    pub fn batch_block(mut self, batch_block: usize) -> EngineBuilder {
        self.config.batch_block = batch_block.max(1);
        self
    }

    /// Force a specific SIMD kernel backend (`None` = runtime detection;
    /// see [`KernelBackend::detected`]).  The `EMDPAR_KERNEL` environment
    /// variable overrides both.
    pub fn kernel(mut self, kernel: KernelBackend) -> EngineBuilder {
        self.config.kernel = Some(kernel);
        self
    }

    /// Compressed stage-1 residency tier ([`CompressedKind::F16`] keeps an
    /// f16 copy of the embedding + centroid tables for candidate scoring;
    /// the planner restores exactness with an exact-f32 rerank).
    pub fn compressed(mut self, compressed: CompressedKind) -> EngineBuilder {
        self.config.compressed = compressed;
        self
    }

    pub fn backend(mut self, backend: Backend) -> EngineBuilder {
        self.config.backend = backend;
        self
    }

    /// Enable the IVF pruning index (trained at
    /// [`EngineBuilder::build_search`] time; see `crate::index`).
    pub fn index(mut self, params: IndexParams) -> EngineBuilder {
        self.config.index = Some(params);
        self
    }

    /// Request default: results per query when a
    /// [`crate::coordinator::SearchRequest`] leaves `l` unset.
    pub fn topl(mut self, l: usize) -> EngineBuilder {
        self.config.topl = l.max(1);
        self
    }

    /// Request default: cascade stage 1 keeps `overfetch × ℓ` candidates
    /// when a [`crate::coordinator::CascadeSpec`] does not carry its own
    /// overfetch.
    pub fn overfetch(mut self, overfetch: usize) -> EngineBuilder {
        self.config.overfetch = overfetch.max(1);
        self
    }

    /// Merge fan-out of the monolithic engine's shard router (rank-time
    /// granularity only; see [`EngineBuilder::sharded`] for the live
    /// sharded corpus).
    pub fn shards(mut self, shards: usize) -> EngineBuilder {
        self.config.shards = shards.max(1);
        self
    }

    /// Split the corpus into a sharded live corpus: per-shard engines (+
    /// per-shard IVF when [`EngineBuilder::index`] is also set) behind a
    /// fan-out / top-ℓ-merge route, appendable at runtime through
    /// [`crate::coordinator::SearchEngine::add_docs`].  See `crate::shard`.
    pub fn sharded(mut self, params: ShardParams) -> EngineBuilder {
        self.config.sharded = Some(params);
        self
    }

    /// Replace the whole remote fan-out block (see [`RemoteParams`]):
    /// the coordinator dispatches its sharded fan-out over TCP to the
    /// `emdpar node` replicas named by the topology manifest.  Requires
    /// [`EngineBuilder::sharded`].
    pub fn remote(mut self, params: RemoteParams) -> EngineBuilder {
        self.config.remote = Some(params);
        self
    }

    /// Enable remote fan-out with this topology manifest, keeping the
    /// remaining [`RemoteParams`] at their defaults (or the configured
    /// values when a `remote` block already exists).
    pub fn topology(mut self, path: impl Into<String>) -> EngineBuilder {
        let mut p = self.config.remote.take().unwrap_or_default();
        p.topology = path.into();
        self.config.remote = Some(p);
        self
    }

    pub fn listen(mut self, addr: impl Into<String>) -> EngineBuilder {
        self.config.listen = addr.into();
        self
    }

    pub fn max_batch(mut self, max_batch: usize) -> EngineBuilder {
        self.config.max_batch = max_batch.max(1);
        self
    }

    pub fn linger_ms(mut self, linger_ms: u64) -> EngineBuilder {
        self.config.linger_ms = linger_ms;
        self
    }

    /// Replace the whole serving-runtime block (see [`ServeParams`]).
    pub fn serve(mut self, params: ServeParams) -> EngineBuilder {
        self.config.serve = params;
        self
    }

    /// Reactor threads for the event-loop server
    /// ([`crate::serve::ReactorServer`]).
    pub fn reactors(mut self, reactors: usize) -> EngineBuilder {
        self.config.serve.reactors = reactors.max(1);
        self
    }

    /// Admission budget: searches in flight beyond this are shed with an
    /// `overloaded` error instead of queueing without bound.
    pub fn max_inflight(mut self, max_inflight: usize) -> EngineBuilder {
        self.config.serve.max_inflight = max_inflight.max(1);
        self
    }

    /// Default per-request deadline in milliseconds (0 disables; requests
    /// override with their own `"deadline_ms"`).
    pub fn deadline_ms(mut self, deadline_ms: u64) -> EngineBuilder {
        self.config.serve.deadline_ms = deadline_ms;
        self
    }

    /// Reactor-side idle-connection timeout in milliseconds (0 disables).
    pub fn idle_timeout_ms(mut self, idle_timeout_ms: u64) -> EngineBuilder {
        self.config.serve.idle_timeout_ms = idle_timeout_ms;
        self
    }

    /// Hard request-line length cap (both servers).
    pub fn max_line_bytes(mut self, max_line_bytes: usize) -> EngineBuilder {
        self.config.serve.max_line_bytes = max_line_bytes.max(256);
        self
    }

    /// Slow-query log threshold in µs: requests slower than this are traced
    /// and logged at WARN with their per-stage span breakdown (0 disables;
    /// `EMDPAR_SLOW_QUERY_US` overrides at build time).
    pub fn slow_query_us(mut self, slow_query_us: u64) -> EngineBuilder {
        self.config.serve.slow_query_us = slow_query_us;
        self
    }

    /// Span ring capacity in records (~40 bytes each; clamped to >= 16).
    pub fn trace_buffer(mut self, trace_buffer: usize) -> EngineBuilder {
        self.config.serve.trace_buffer = trace_buffer.max(16);
        self
    }

    /// Telemetry window duration in ms for the sliding-window workload
    /// store (0 leaves telemetry disarmed).
    pub fn telemetry_window_ms(mut self, telemetry_window_ms: u64) -> EngineBuilder {
        self.config.serve.telemetry_window_ms = telemetry_window_ms;
        self
    }

    /// Online recall auditing: replay 1 in `audit_sample` served searches
    /// at full probe off the hot path (0 disables).
    pub fn audit_sample(mut self, audit_sample: u64) -> EngineBuilder {
        self.config.serve.audit_sample = audit_sample;
        self
    }

    /// The effective configuration so far.
    pub fn config(&self) -> &Config {
        &self.config
    }

    /// A [`MethodRegistry`] matching this builder's ground metric.
    pub fn registry(&self) -> MethodRegistry {
        MethodRegistry::new(self.config.metric)
    }

    fn materialize(&self) -> EmdResult<Arc<Dataset>> {
        match &self.dataset {
            Some(ds) => Ok(Arc::clone(ds)),
            None => Ok(Arc::new(self.config.load_dataset()?)),
        }
    }

    /// Validate, materialize the dataset, and build the batched CPU engine.
    pub fn build_lc(self) -> EmdResult<LcEngine> {
        self.config.validate()?;
        let dataset = self.materialize()?;
        Ok(LcEngine::new(
            dataset,
            EngineParams {
                metric: self.config.metric,
                threads: self.config.threads,
                symmetric: self.config.symmetric,
                batch_block: self.config.batch_block,
                kernel: self.config.kernel,
                compressed: self.config.compressed,
            },
        ))
    }

    /// Validate, materialize the dataset, and build the serving engine
    /// (connects the PJRT runtime when `backend = artifact`).
    pub fn build_search(self) -> EmdResult<SearchEngine> {
        self.config.validate()?;
        let dataset = self.materialize()?;
        SearchEngine::with_dataset(self.config, dataset)
    }
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Distance, Histogram};

    fn spec() -> DatasetSpec {
        DatasetSpec::SynthText { n: 24, vocab: 120, dim: 6, seed: 9 }
    }

    #[test]
    fn builds_lc_engine_with_overrides() {
        let eng = EngineBuilder::new()
            .dataset_spec(spec())
            .metric(Metric::L2)
            .threads(2)
            .symmetric(false)
            .build_lc()
            .unwrap();
        assert_eq!(eng.dataset().len(), 24);
        assert_eq!(eng.params().threads, 2);
        assert!(!eng.params().symmetric);
        let row = eng.distances(&eng.dataset().histogram(0), Method::Rwmd);
        assert_eq!(row.len(), 24);
    }

    #[test]
    fn builds_search_engine_and_searches() {
        let eng = EngineBuilder::new()
            .dataset_spec(spec())
            .method(Method::Act { k: 2 })
            .threads(2)
            .topl(3)
            .overfetch(4)
            .shards(2)
            .build_search()
            .unwrap();
        assert_eq!(eng.config().overfetch, 4);
        let q = eng.dataset().histogram(1);
        // builder knobs are the request defaults: an empty request resolves
        // to method ACT-1, top-3
        let resp = eng.execute(&crate::coordinator::SearchRequest::query(q)).unwrap();
        assert_eq!(resp.plan.method, Method::Act { k: 2 });
        assert_eq!(resp.plan.l, 3);
        let res = &resp.results[0];
        assert_eq!(res.hits.len(), 3);
        assert_eq!(res.hits[0].1, 1);
    }

    #[test]
    fn shared_dataset_is_not_copied() {
        let ds = Arc::new(
            Config { dataset: spec(), ..Default::default() }.load_dataset().unwrap(),
        );
        let eng = EngineBuilder::new().dataset(Arc::clone(&ds)).threads(1).build_lc().unwrap();
        assert_eq!(eng.dataset().len(), ds.len());
        // 1 here + 1 in the engine
        assert_eq!(Arc::strong_count(&ds), 2);
    }

    #[test]
    fn serve_knobs_flow_into_config() {
        let b = EngineBuilder::new()
            .dataset_spec(spec())
            .reactors(4)
            .max_inflight(128)
            .deadline_ms(250)
            .idle_timeout_ms(30_000)
            .max_line_bytes(0) // clamps to the floor
            .slow_query_us(150_000)
            .trace_buffer(1) // clamps to the floor
            .telemetry_window_ms(500)
            .audit_sample(64);
        assert_eq!(b.config().serve.reactors, 4);
        assert_eq!(b.config().serve.max_inflight, 128);
        assert_eq!(b.config().serve.deadline_ms, 250);
        assert_eq!(b.config().serve.idle_timeout_ms, 30_000);
        assert_eq!(b.config().serve.max_line_bytes, 256);
        assert_eq!(b.config().serve.slow_query_us, 150_000);
        assert_eq!(b.config().serve.trace_buffer, 16);
        assert_eq!(b.config().serve.telemetry_window_ms, 500);
        assert_eq!(b.config().serve.audit_sample, 64);
        let eng = b.build_search().unwrap();
        assert_eq!(eng.config().serve.max_inflight, 128);
        assert!(eng.slow_query_us() >= 150_000 || std::env::var("EMDPAR_SLOW_QUERY_US").is_ok());
        assert!(eng.tracer().capacity() >= 16);
        assert!(eng.telemetry().armed(), "window > 0 arms the store");
        assert_eq!(eng.telemetry().window_ms(), 500);
        assert_eq!(eng.auditor().sample(), 64);
    }

    #[test]
    fn remote_knobs_flow_into_config() {
        let b = EngineBuilder::new()
            .dataset_spec(spec())
            .sharded(ShardParams::default())
            .topology("topo.json");
        assert_eq!(b.config().remote.as_ref().unwrap().topology, "topo.json");
        // topology() on an existing block repoints only the manifest path
        let b = b
            .remote(RemoteParams { topology: "a.json".into(), hedge_ms: 0, ..Default::default() })
            .topology("b.json");
        let rp = b.config().remote.as_ref().unwrap();
        assert_eq!(rp.topology, "b.json");
        assert_eq!(rp.hedge_ms, 0);
        // remote fan-out without a sharded corpus is rejected at build
        let err = EngineBuilder::new().dataset_spec(spec()).topology("t.json").build_search();
        assert!(err.is_err());
    }

    #[test]
    fn kernel_and_compressed_knobs_flow_into_engines() {
        let eng = EngineBuilder::new()
            .dataset_spec(spec())
            .threads(1)
            .kernel(KernelBackend::Scalar)
            .compressed(CompressedKind::F16)
            .build_lc()
            .unwrap();
        assert_eq!(eng.params().kernel, Some(KernelBackend::Scalar));
        assert!(eng.compressed_active());
    }

    #[test]
    fn invalid_config_is_rejected_at_build() {
        let err = EngineBuilder::new()
            .dataset_spec(spec())
            .method(Method::Act { k: 1000 })
            .build_lc();
        assert!(err.is_err());
    }

    #[test]
    fn builder_registry_serves_every_method() {
        let b = EngineBuilder::new().dataset_spec(spec());
        let registry = b.registry();
        let eng = b.build_lc().unwrap();
        let q: Histogram = eng.dataset().histogram(0);
        for m in MethodRegistry::methods() {
            let d = registry.distance(m);
            let v = d.distance(&eng.dataset().embeddings, &q, &q).unwrap();
            assert!(v.is_finite(), "{m}");
        }
    }
}
