"""Layer-2 JAX model: the LC-ACT pipeline (paper Fig. 5-7) composed from the
Layer-1 Pallas kernels.

Entry points (all functional, all jit-able, all AOT-lowered by aot.py):

* :func:`phase1` — per-query preprocessing: distance matrix D (v, h),
  top-k distances Z (v, k), capacity matrix W (v, k) = qw[S].  Runs once
  per query and is reused across every database tile.
* :func:`phase2` — per-tile Phases 2+3: iterative constrained transfers of
  a database tile X (n, v) towards the query, returning the ACT-(k-1)
  direction-A lower bounds t (n,).
* :func:`rwmd_direction_b` — the opposite asymmetric RWMD bound via the
  masked min-plus product (used for the symmetric max in the evaluation).
* :func:`lc_act_fused` — phase1+phase2 in a single computation, convenient
  for the quickstart and for single-shot comparisons.

The Rust coordinator (rust/src/runtime) loads the lowered HLO of these
functions and drives them from the request path; Python is never imported
at run time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import (
    constrained_transfers,
    pairwise_distance,
    row_topk,
    rwmd_direction_b as _rwmd_b_kernel,
)


def phase1(v: jax.Array, q: jax.Array, qw: jax.Array, k: int):
    """Per-query Phase 1: distances, top-k and capacities.

    Args:
      v:  (v, m) vocabulary embeddings.
      q:  (h, m) query coordinates.
      qw: (h,)   query weights (L1-normalized; padding bins carry 0).
      k:  static number of transfer targets (ACT-(k-1)).

    Returns:
      d: (v, h) distance matrix (needed by the direction-B kernel),
      z: (v, k) ascending top-k distances per vocabulary coordinate,
      w: (v, k) matching query-bin weights (transfer capacities).
    """
    d = pairwise_distance(v, q)
    z, s = row_topk(d, k)
    w = jnp.take(qw, s)  # gather capacities; L2-level op, fuses into HLO
    return d, z, w


def phase2(x: jax.Array, z: jax.Array, w: jax.Array) -> jax.Array:
    """Phases 2+3 for one database tile: ACT-(k-1) direction-A bounds."""
    return constrained_transfers(x, z, w)


def rwmd_direction_b(x: jax.Array, d: jax.Array, qw: jax.Array) -> jax.Array:
    """Direction-B RWMD bounds for one database tile."""
    return _rwmd_b_kernel(x, d, qw)


def lc_act_fused(v: jax.Array, q: jax.Array, qw: jax.Array, x: jax.Array, k: int):
    """Whole pipeline in one computation: (t_a, t_b_rwmd).

    t_a is the ACT-(k-1) direction-A bound, t_b the RWMD direction-B bound;
    the coordinator takes the element-wise max of the asymmetric bounds for
    the symmetric measure exactly as the paper's evaluation does (Section 6).
    """
    d, z, w = phase1(v, q, qw, k)
    t_a = phase2(x, z, w)
    t_b = rwmd_direction_b(x, d, qw)
    return t_a, t_b
