"""AOT compiler: lower the Layer-2 entry points to HLO **text** artifacts.

HLO text (not ``serialize()``-d ``HloModuleProto``) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the Rust
side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/load_hlo/ for the reference wiring.

Every artifact has **static** shapes; the Rust runtime pads/tiles queries
and database shards to the artifact menu recorded in ``manifest.json``.

Usage:  cd python && python -m compile.aot --out ../artifacts [--profile all]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Artifact shape menu.
#
# profile -> (v, h, m, n_tile, k list).  "dev" is the small profile used by
# tests and the quickstart; "mnist" matches the paper's image experiments
# (28x28 = 784-bin histograms, m=2 pixel coordinates); "text" matches the
# synthetic 20News-scale experiments (high-m embeddings, sparse docs).
# ---------------------------------------------------------------------------
PROFILES = {
    "dev": dict(v=256, h=64, m=16, n=128, ks=(1, 2, 4, 8)),
    "mnist": dict(v=784, h=784, m=2, n=256, ks=(1, 2, 4, 8, 16)),
    "text": dict(v=4096, h=256, m=64, n=128, ks=(1, 2, 8)),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def build_entries(profile: str, cfg: dict):
    """Yield (name, lowered, manifest-entry) triples for one profile."""
    v, h, m, n, ks = cfg["v"], cfg["h"], cfg["m"], cfg["n"], cfg["ks"]
    f32 = jnp.float32
    sv = jax.ShapeDtypeStruct((v, m), f32)
    sq = jax.ShapeDtypeStruct((h, m), f32)
    sqw = jax.ShapeDtypeStruct((h,), f32)
    sx = jax.ShapeDtypeStruct((n, v), f32)
    sd = jax.ShapeDtypeStruct((v, h), f32)

    for k in ks:
        szk = jax.ShapeDtypeStruct((v, k), f32)

        name = f"{profile}_phase1_k{k}"
        fn = jax.jit(lambda V, Q, QW, _k=k: model.phase1(V, Q, QW, _k))
        yield name, fn.lower(sv, sq, sqw), {
            "entry": "phase1",
            "profile": profile,
            "v": v, "h": h, "m": m, "n": n, "k": k,
            "inputs": [_spec((v, m)), _spec((h, m)), _spec((h,))],
            "outputs": [_spec((v, h)), _spec((v, k)), _spec((v, k))],
        }

        name = f"{profile}_phase2_k{k}"
        fn = jax.jit(model.phase2)
        yield name, fn.lower(sx, szk, szk), {
            "entry": "phase2",
            "profile": profile,
            "v": v, "h": h, "m": m, "n": n, "k": k,
            "inputs": [_spec((n, v)), _spec((v, k)), _spec((v, k))],
            "outputs": [_spec((n,))],
        }

        name = f"{profile}_fused_k{k}"
        fn = jax.jit(lambda V, Q, QW, X, _k=k: model.lc_act_fused(V, Q, QW, X, _k))
        yield name, fn.lower(sv, sq, sqw, sx), {
            "entry": "fused",
            "profile": profile,
            "v": v, "h": h, "m": m, "n": n, "k": k,
            "inputs": [_spec((v, m)), _spec((h, m)), _spec((h,)), _spec((n, v))],
            "outputs": [_spec((n,)), _spec((n,))],
        }

    name = f"{profile}_rwmd_b"
    fn = jax.jit(model.rwmd_direction_b)
    yield name, fn.lower(sx, sd, sqw), {
        "entry": "rwmd_b",
        "profile": profile,
        "v": v, "h": h, "m": m, "n": n, "k": 1,
        "inputs": [_spec((n, v)), _spec((v, h)), _spec((h,))],
        "outputs": [_spec((n,))],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--profile",
        default="all",
        choices=[*PROFILES.keys(), "all"],
        help="which shape profile(s) to emit",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    profiles = list(PROFILES) if args.profile == "all" else [args.profile]
    manifest = {"format": "hlo-text-v1", "artifacts": {}}
    for prof in profiles:
        for name, lowered, entry in build_entries(prof, PROFILES[prof]):
            text = to_hlo_text(lowered)
            path = os.path.join(args.out, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            entry["file"] = f"{name}.hlo.txt"
            manifest["artifacts"][name] = entry
            print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
