"""Phase-1 kernel: row-wise top-k smallest selection with indices.

For each vocabulary coordinate (row of the ``(v, h)`` distance matrix) the
LC-ACT Phase 1 needs the k smallest distances to the query coordinates
(``Z``) together with the query-bin indices that produced them (``S``).

k is tiny (1..16), so instead of sorting each row (the GPU version uses a
bitonic sort) the kernel performs k masked argmin passes over the row tile
— a branchless selection that vectorizes on the VPU and needs no scratch
beyond the (bv, h) tile itself.

Tie-breaking is "lowest index first" (``jnp.argmin`` semantics); the Rust
CPU engine mirrors this exactly so artifact and native paths agree
bit-for-bit on ties.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_MASK = 3.0e38  # sentinel larger than any real distance (python float: do
# not use a jnp scalar here — pallas would treat it as a captured constant)


def _topk_kernel(d_ref, z_ref, s_ref, *, k: int):
    d = d_ref[...].astype(jnp.float32)  # (bv, h)
    bv, h = d.shape
    work = d
    rows = jnp.arange(bv)
    zs = []
    ss = []
    for _ in range(k):
        idx = jnp.argmin(work, axis=1)  # first occurrence on ties
        val = jnp.take_along_axis(work, idx[:, None], axis=1)[:, 0]
        zs.append(val)
        ss.append(idx.astype(jnp.int32))
        work = work.at[rows, idx].set(_MASK)
    z_ref[...] = jnp.stack(zs, axis=1)
    s_ref[...] = jnp.stack(ss, axis=1)


def _pick_block(n: int, target: int = 128) -> int:
    for b in range(min(n, target), 0, -1):
        if n % b == 0:
            return b
    return 1


@functools.partial(jax.jit, static_argnames=("k", "block_v"))
def row_topk(d: jax.Array, k: int, *, block_v: int | None = None):
    """Top-k smallest entries per row of ``d``.

    Args:
      d: ``(v, h)`` float32 distance matrix.
      k: number of smallest entries to select per row; ``k <= h``.
      block_v: row tile height; must divide ``v``.

    Returns:
      ``(z, s)`` where ``z`` is ``(v, k)`` float32 values in ascending
      order and ``s`` is ``(v, k)`` int32 column indices.
    """
    nv, h = d.shape
    assert 1 <= k <= h, f"k={k} must be in [1, h={h}]"
    bv = block_v if block_v is not None else _pick_block(nv)
    assert nv % bv == 0, f"block_v={bv} must divide v={nv}"

    kernel = functools.partial(_topk_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(nv // bv,),
        in_specs=[pl.BlockSpec((bv, h), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bv, k), lambda i: (i, 0)),
            pl.BlockSpec((bv, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nv, k), jnp.float32),
            jax.ShapeDtypeStruct((nv, k), jnp.int32),
        ],
        interpret=True,
    )(d)
