"""Layer-1 Pallas kernels for the LC-ACT pipeline.

Every kernel is written for TPU-shaped execution (VMEM tiles, MXU matmuls,
VPU element-wise maps) but lowered with ``interpret=True`` so the resulting
HLO runs on any PJRT backend, including the Rust CPU client on the request
path.  Correctness oracles live in :mod:`ref` and are enforced by pytest.
"""

from .distance import pairwise_distance
from .topk import row_topk
from .transfers import constrained_transfers, rwmd_direction_b

__all__ = [
    "pairwise_distance",
    "row_topk",
    "constrained_transfers",
    "rwmd_direction_b",
]
