"""Pure-numpy correctness oracles for every kernel and for the paper's
per-pair algorithms (RWMD, OMR / Algorithm 1, ICT / Algorithm 2,
ACT / Algorithm 3), plus an exact-EMD LP oracle.

These are the ground truth pytest compares the Pallas kernels and the
composed LC pipeline against; the Rust test-suite mirrors the same
semantics (including tie-breaking) so all three implementations agree.
"""

from __future__ import annotations

import numpy as np

BIG = 3.0e38


# ---------------------------------------------------------------------------
# Kernel-level oracles
# ---------------------------------------------------------------------------


def pairwise_distance_ref(v: np.ndarray, q: np.ndarray) -> np.ndarray:
    """(v, h) Euclidean distances between rows of V and rows of Q."""
    diff = v[:, None, :].astype(np.float64) - q[None, :, :].astype(np.float64)
    return np.sqrt(np.maximum((diff * diff).sum(-1), 0.0)).astype(np.float32)


def row_topk_ref(d: np.ndarray, k: int):
    """k smallest per row, ascending, ties broken by lowest column index."""
    # stable argsort reproduces iterative-argmin tie-breaking
    order = np.argsort(d, axis=1, kind="stable")[:, :k].astype(np.int32)
    vals = np.take_along_axis(d, order, axis=1).astype(np.float32)
    return vals, order


def constrained_transfers_ref(x: np.ndarray, z: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Equations (6)-(9): k-1 capacity-constrained moves + Phase-3 remainder."""
    x = x.astype(np.float64).copy()
    k = z.shape[1]
    t = np.zeros(x.shape[0], np.float64)
    for l in range(k - 1):
        y = np.minimum(x, w[:, l].astype(np.float64)[None, :])
        x -= y
        t += y @ z[:, l].astype(np.float64)
    t += x @ z[:, k - 1].astype(np.float64)
    return t.astype(np.float32)


def rwmd_direction_b_ref(x: np.ndarray, d: np.ndarray, qw: np.ndarray) -> np.ndarray:
    """For each doc u: sum_j qw_j * min_{i in supp(x_u)} D[i, j]."""
    n = x.shape[0]
    out = np.zeros(n, np.float64)
    for u in range(n):
        supp = x[u] > 0
        if not supp.any():
            continue  # padding row: zero cost
        r = d[supp].min(axis=0).astype(np.float64)
        out[u] = float(r @ qw.astype(np.float64))
    return out.astype(np.float32)


# ---------------------------------------------------------------------------
# LC pipeline oracle (direction A: move each database histogram into q)
# ---------------------------------------------------------------------------


def lc_act_ref(v: np.ndarray, q: np.ndarray, qw: np.ndarray, x: np.ndarray, k: int):
    """Full Phase 1 -> Phase 2/3 reference; returns (t, d, z, s, w)."""
    d = pairwise_distance_ref(v, q)
    z, s = row_topk_ref(d, k)
    w = qw[s]
    t = constrained_transfers_ref(x, z, w)
    return t, d, z, s, w


# ---------------------------------------------------------------------------
# Per-pair algorithms exactly as printed in the paper
# ---------------------------------------------------------------------------


def rwmd_pair_ref(p: np.ndarray, q: np.ndarray, c: np.ndarray) -> float:
    """One-directional RWMD: each bin of p moves to its closest bin of q."""
    return float(p.astype(np.float64) @ c.min(axis=1).astype(np.float64))


def omr_pair_ref(p: np.ndarray, q: np.ndarray, c: np.ndarray) -> float:
    """Algorithm 1 (Overlapping Mass Reduction), direction p -> q."""
    t = 0.0
    for i in range(len(p)):
        pi = float(p[i])
        if pi == 0.0:
            continue
        row = c[i]
        s1 = int(np.argmin(row))
        if row[s1] == 0.0:
            masked = row.astype(np.float64).copy()
            masked[s1] = BIG
            s2 = int(np.argmin(masked))
            r = min(pi, float(q[s1]))
            pi -= r
            t += pi * float(row[s2])
        else:
            t += pi * float(row[s1])
    return t


def ict_pair_ref(p: np.ndarray, q: np.ndarray, c: np.ndarray) -> float:
    """Algorithm 2 (Iterative Constrained Transfers), direction p -> q."""
    t = 0.0
    for i in range(len(p)):
        pi = float(p[i])
        if pi == 0.0:
            continue
        order = np.argsort(c[i], kind="stable")
        for j in order:
            if pi <= 1e-15:
                break
            r = min(pi, float(q[j]))
            pi -= r
            t += r * float(c[i, j])
    return t


def act_pair_ref(p: np.ndarray, q: np.ndarray, c: np.ndarray, k: int) -> float:
    """Algorithm 3 (Approximate ICT with k-1 constrained iterations)."""
    t = 0.0
    for i in range(len(p)):
        pi = float(p[i])
        if pi == 0.0:
            continue
        vals, order = row_topk_ref(c[i : i + 1], k)
        order, vals = order[0], vals[0]
        for l in range(k - 1):
            r = min(pi, float(q[order[l]]))
            pi -= r
            t += r * float(vals[l])
        if pi > 1e-15:
            t += pi * float(vals[k - 1])
    return t


def emd_pair_ref(p: np.ndarray, q: np.ndarray, c: np.ndarray) -> float:
    """Exact EMD via the transportation LP (scipy linprog / HiGHS).

    Tiny-instance oracle used to validate the Theorem-2 chain and the Rust
    network-flow solver.  Requires sum(p) == sum(q).
    """
    from scipy.optimize import linprog

    hp, hq = c.shape
    # Equality constraints: out-flow per source row, in-flow per sink col.
    a_eq = np.zeros((hp + hq, hp * hq))
    for i in range(hp):
        a_eq[i, i * hq : (i + 1) * hq] = 1.0
    for j in range(hq):
        a_eq[hp + j, j::hq] = 1.0
    b_eq = np.concatenate([p, q]).astype(np.float64)
    res = linprog(c.reshape(-1).astype(np.float64), A_eq=a_eq, b_eq=b_eq,
                  bounds=(0, None), method="highs")
    assert res.status == 0, f"LP failed: {res.message}"
    return float(res.fun)
