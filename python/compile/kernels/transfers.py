"""Phase-2/3 kernels: iterative constrained transfers and RWMD direction B.

``constrained_transfers`` implements equations (6)-(9) of the paper: for a
tile of database histograms X (rows = documents, columns = vocabulary
coordinates), iteration l moves the largest mass allowed by the capacity
``W[:, l]`` (the weight of the query bin that is l-th closest to each
vocabulary coordinate) at cost ``Z[:, l]`` (the l-th smallest distance),
and Phase 3 ships whatever is left at the k-th smallest distance:

    for l in 1..k-1:   Y = min(X, w_l);  X -= Y;  t += Y . z_l
    t += X . z_k

All k iterations are fused into a single kernel so the residual tile X
stays in VMEM for the whole transfer schedule (the GPU version re-reads
global memory every iteration); the per-iteration dot products run on the
MXU as (bn, v) x (v,) GEMVs.

``rwmd_direction_b`` computes the opposite asymmetric RWMD bound (moving
the query into each database histogram): for every document u and query
bin j it needs ``min_{i in supp(x_u)} D[i, j]`` — a masked min-plus product
between the histogram tile and the distance matrix, streamed over vocabulary
chunks so the ``(bn, vc, h)`` broadcast stays inside VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 3.0e38  # python float: jnp scalars become captured pallas constants


def _transfers_kernel(x_ref, z_ref, w_ref, t_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)  # (bn, v) residual mass
    z = z_ref[...].astype(jnp.float32)  # (v, k) ascending distances
    w = w_ref[...].astype(jnp.float32)  # (v, k) capacities
    t = jnp.zeros((x.shape[0],), jnp.float32)
    for l in range(k - 1):
        y = jnp.minimum(x, w[:, l][None, :])  # capacity-constrained move
        x = x - y
        t = t + jnp.dot(y, z[:, l], preferred_element_type=jnp.float32)
    # Phase 3: remaining mass moves at the k-th smallest distance.
    t = t + jnp.dot(x, z[:, k - 1], preferred_element_type=jnp.float32)
    t_ref[...] = t


def _pick_block(n: int, target: int = 128) -> int:
    for b in range(min(n, target), 0, -1):
        if n % b == 0:
            return b
    return 1


@functools.partial(jax.jit, static_argnames=("block_n",))
def constrained_transfers(
    x: jax.Array, z: jax.Array, w: jax.Array, *, block_n: int | None = None
) -> jax.Array:
    """LC-ACT Phases 2+3 over a database tile.

    Args:
      x: ``(n, v)`` float32 database histogram tile (dense, rows L1-normalized).
      z: ``(v, k)`` float32 top-k smallest vocabulary-to-query distances.
      w: ``(v, k)`` float32 matching query-bin weights (capacities).
      block_n: document tile height; must divide ``n``.

    Returns:
      ``(n,)`` float32 transport-cost lower bounds (ACT-(k-1) direction A).
    """
    n, v = x.shape
    v2, k = z.shape
    assert v == v2 and z.shape == w.shape, "Z/W must be (v, k)"
    bn = block_n if block_n is not None else _pick_block(n)
    assert n % bn == 0, f"block_n={bn} must divide n={n}"

    kernel = functools.partial(_transfers_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, v), lambda i: (i, 0)),
            pl.BlockSpec((v, k), lambda i: (0, 0)),
            pl.BlockSpec((v, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, z, w)


def _rwmd_b_kernel(x_ref, d_ref, qw_ref, t_ref, *, chunk: int):
    x = x_ref[...].astype(jnp.float32)  # (bn, v)
    d = d_ref[...].astype(jnp.float32)  # (v, h)
    qw = qw_ref[...].astype(jnp.float32)  # (h,)
    bn, v = x.shape
    h = d.shape[1]
    r = jnp.full((bn, h), _BIG, jnp.float32)
    # Stream the vocabulary axis in chunks to bound the (bn, chunk, h)
    # broadcast working set (VMEM-resident on TPU).
    for c in range(0, v, chunk):
        xc = x[:, c : c + chunk]  # (bn, vc)
        dc = d[c : c + chunk, :]  # (vc, h)
        cand = jnp.where(xc[:, :, None] > 0.0, dc[None, :, :], _BIG)
        r = jnp.minimum(r, jnp.min(cand, axis=1))
    # Documents whose support misses the chunk entirely keep _BIG entries;
    # an all-zero (padding) row contributes qw . _BIG, which the Rust side
    # masks out, but guard with where() so padded rows read as 0 cost.
    r = jnp.where(r >= _BIG, 0.0, r)
    t_ref[...] = jnp.dot(r, qw, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "chunk"))
def rwmd_direction_b(
    x: jax.Array,
    d: jax.Array,
    qw: jax.Array,
    *,
    block_n: int | None = None,
    chunk: int = 128,
) -> jax.Array:
    """RWMD lower bound for moving the query into each database histogram.

    Args:
      x: ``(n, v)`` float32 database histogram tile.
      d: ``(v, h)`` float32 vocabulary-to-query distance matrix (Phase 1).
      qw: ``(h,)`` float32 query weights.
      block_n: document tile height; must divide ``n``.
      chunk: vocabulary streaming chunk for the masked min reduction.

    Returns:
      ``(n,)`` float32 direction-B RWMD lower bounds.
    """
    n, v = x.shape
    v2, h = d.shape
    assert v == v2 and qw.shape == (h,)
    bn = block_n if block_n is not None else _pick_block(n, 64)
    assert n % bn == 0, f"block_n={bn} must divide n={n}"

    kernel = functools.partial(_rwmd_b_kernel, chunk=min(chunk, v))
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, v), lambda i: (i, 0)),
            pl.BlockSpec((v, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, d, qw)
