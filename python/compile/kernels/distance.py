"""Phase-1 kernel: pairwise Euclidean distances vocabulary x query.

Computes ``D[i, j] = || V[i] - Q[j] ||_2`` for a ``(v, m)`` vocabulary
embedding matrix and an ``(h, m)`` query coordinate matrix via the expansion

    D^2 = ||V||^2 - 2 V Q^T + ||Q||^2

so the dominant cost is a single GEMM that maps onto the MXU systolic
array.  The kernel tiles the vocabulary into ``(bv, m)`` VMEM blocks (the
grid walks the vocabulary axis); the query block is small (h*m floats) and
stays resident in VMEM across all grid steps.

TPU adaptation of the paper's GPU Phase 1 (threadblock GEMM + epilogue):
the norm/epilogue work runs on the VPU fused into the same kernel, so D is
written to HBM exactly once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _distance_kernel(v_ref, q_ref, o_ref):
    """One grid step: distances from a vocabulary tile to the whole query."""
    vb = v_ref[...].astype(jnp.float32)  # (bv, m)
    qb = q_ref[...].astype(jnp.float32)  # (h, m)
    # MXU: (bv, m) x (m, h) -> (bv, h)
    gram = jnp.dot(vb, qb.T, preferred_element_type=jnp.float32)
    vn = jnp.sum(vb * vb, axis=1, keepdims=True)  # (bv, 1)  VPU
    qn = jnp.sum(qb * qb, axis=1, keepdims=True).T  # (1, h)   VPU
    d2 = vn - 2.0 * gram + qn
    # The Gram expansion cancels catastrophically when V[i] == Q[j]; the
    # residual noise is O(eps * (|v|^2 + |q|^2)).  Overlapping coordinates
    # MUST produce an exact 0 (OMR's free-transfer rule and the paper's
    # Theorem-3 effectiveness argument key off C[i,j] == 0), so snap
    # everything below the cancellation noise floor to zero.  For the
    # paper's data this is safe: distinct MNIST pixels are >= 1 apart and
    # distinct word embeddings are far above the 1e-6 relative floor.
    scale = vn + qn
    d2 = jnp.where(d2 <= 1e-6 * scale, 0.0, d2)
    o_ref[...] = jnp.sqrt(jnp.maximum(d2, 0.0))


def _pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (VMEM tile height)."""
    for b in range(min(n, target), 0, -1):
        if n % b == 0:
            return b
    return 1


@functools.partial(jax.jit, static_argnames=("block_v",))
def pairwise_distance(v: jax.Array, q: jax.Array, *, block_v: int | None = None) -> jax.Array:
    """Full ``(v, h)`` Euclidean distance matrix between rows of V and Q.

    Args:
      v: ``(v, m)`` float32 vocabulary embeddings.
      q: ``(h, m)`` float32 query coordinates.
      block_v: vocabulary tile height; must divide ``v``.  Defaults to the
        largest divisor of ``v`` no greater than 128 (8 MXU sublanes x 16).

    Returns:
      ``(v, h)`` float32 matrix of L2 distances.
    """
    nv, m = v.shape
    h, m2 = q.shape
    assert m == m2, f"dimension mismatch: V has m={m}, Q has m={m2}"
    bv = block_v if block_v is not None else _pick_block(nv)
    assert nv % bv == 0, f"block_v={bv} must divide v={nv}"

    return pl.pallas_call(
        _distance_kernel,
        grid=(nv // bv,),
        in_specs=[
            pl.BlockSpec((bv, m), lambda i: (i, 0)),
            pl.BlockSpec((h, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bv, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nv, h), jnp.float32),
        interpret=True,
    )(v, q)
