"""Row-wise top-k kernel: values, indices, tie-breaking, edge cases."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose, assert_array_equal

from compile.kernels import row_topk
from compile.kernels.ref import row_topk_ref


@pytest.mark.parametrize("v,h,k", [(8, 4, 1), (64, 16, 4), (32, 50, 16), (16, 8, 8)])
def test_matches_reference(v, h, k):
    rng = np.random.default_rng(v + h + k)
    d = rng.uniform(size=(v, h)).astype(np.float32)
    z, s = row_topk(d, k)
    zr, sr = row_topk_ref(d, k)
    assert_allclose(np.asarray(z), zr, rtol=1e-6)
    assert_array_equal(np.asarray(s), sr)


def test_ascending_order():
    rng = np.random.default_rng(7)
    d = rng.uniform(size=(40, 30)).astype(np.float32)
    z, _ = row_topk(d, 8)
    z = np.asarray(z)
    assert (np.diff(z, axis=1) >= 0).all()


def test_tie_breaking_lowest_index_first():
    # All-equal row: indices must come out 0,1,2,...,k-1.
    d = np.ones((4, 10), np.float32)
    _, s = row_topk(d, 5)
    assert_array_equal(np.asarray(s), np.tile(np.arange(5, dtype=np.int32), (4, 1)))


def test_k_equals_h_is_full_sort():
    rng = np.random.default_rng(9)
    d = rng.uniform(size=(12, 6)).astype(np.float32)
    z, s = row_topk(d, 6)
    assert_allclose(np.asarray(z), np.sort(d, axis=1), rtol=1e-6)
    assert_array_equal(np.asarray(s), np.argsort(d, axis=1, kind="stable"))


def test_k1_is_rowmin():
    rng = np.random.default_rng(11)
    d = rng.uniform(size=(25, 13)).astype(np.float32)
    z, s = row_topk(d, 1)
    assert_allclose(np.asarray(z)[:, 0], d.min(axis=1), rtol=1e-6)
    assert_array_equal(np.asarray(s)[:, 0], d.argmin(axis=1).astype(np.int32))


def test_duplicates_within_row_are_kept():
    # Two zeros in one row: both must appear in the top-2.
    d = np.full((1, 6), 5.0, np.float32)
    d[0, 2] = 0.0
    d[0, 4] = 0.0
    z, s = row_topk(d, 3)
    assert_allclose(np.asarray(z)[0], [0.0, 0.0, 5.0])
    assert_array_equal(np.asarray(s)[0, :2], [2, 4])


@settings(max_examples=25, deadline=None)
@given(
    v=st.integers(1, 64),
    h=st.integers(1, 40),
    data=st.data(),
)
def test_hypothesis_sweep(v, h, data):
    k = data.draw(st.integers(1, h))
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    # quantize to provoke ties
    d = (rng.integers(0, 7, size=(v, h)) / 3.0).astype(np.float32)
    z, s = row_topk(d, k)
    zr, sr = row_topk_ref(d, k)
    assert_allclose(np.asarray(z), zr, rtol=1e-6)
    assert_array_equal(np.asarray(s), sr)
