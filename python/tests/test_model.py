"""Layer-2 pipeline: composition, per-pair equivalence, paper semantics."""

from __future__ import annotations

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref
from tests.conftest import make_instance


def test_fused_matches_composed(small_instance):
    vv, q, qw, x = small_instance
    k = 4
    d, z, w = model.phase1(vv, q, qw, k)
    t_a = np.asarray(model.phase2(x, z, w))
    t_b = np.asarray(model.rwmd_direction_b(x, d, qw))
    fa, fb = model.lc_act_fused(vv, q, qw, x, k)
    assert_allclose(np.asarray(fa), t_a, rtol=1e-6)
    assert_allclose(np.asarray(fb), t_b, rtol=1e-6)


def test_pipeline_matches_numpy_reference(small_instance):
    vv, q, qw, x = small_instance
    for k in (1, 2, 4, 8):
        fa, fb = model.lc_act_fused(vv, q, qw, x, k)
        tr, dr, *_ = ref.lc_act_ref(vv, q, qw, x, k)
        tbr = ref.rwmd_direction_b_ref(x, dr, qw)
        assert_allclose(np.asarray(fa), tr, rtol=1e-4, atol=1e-6)
        assert_allclose(np.asarray(fb), tbr, rtol=1e-4, atol=1e-6)


def test_lc_equals_per_pair_act():
    """LC-ACT on a database tile == Algorithm 3 run pair-by-pair.

    This is the core semantic claim of Section 5: the vocabulary-factored
    batched pipeline computes exactly the per-pair ACT values.
    """
    vv, q, qw, x = make_instance(42, v=48, h=12, m=6, n=20)
    c_full = ref.pairwise_distance_ref(vv, q).astype(np.float64)
    for k in (1, 2, 4, 8):
        t, *_ = ref.lc_act_ref(vv, q, qw, x, k)
        fa, _ = model.lc_act_fused(vv, q, qw, x, k)
        for u in range(x.shape[0]):
            supp = np.nonzero(x[u])[0]
            p = x[u][supp]
            c = c_full[supp]
            expected = ref.act_pair_ref(p, qw, c, k)
            assert abs(float(t[u]) - expected) < 1e-4
            assert abs(float(np.asarray(fa)[u]) - expected) < 1e-3


def test_lc_rwmd_special_case():
    """k=1 pipeline == classic RWMD direction A (nearest-coordinate dot)."""
    vv, q, qw, x = make_instance(7, v=32, h=10, m=4, n=12)
    fa, _ = model.lc_act_fused(vv, q, qw, x, 1)
    c_full = ref.pairwise_distance_ref(vv, q).astype(np.float64)
    for u in range(x.shape[0]):
        supp = np.nonzero(x[u])[0]
        expected = ref.rwmd_pair_ref(x[u][supp], qw, c_full[supp])
        assert abs(float(np.asarray(fa)[u]) - expected) < 1e-4


def test_identical_histogram_act_zero():
    """Dense identical p==q with k>=2: every coordinate overlaps with mass
    capacity == its own weight, so the bound is 0 — and stays 0 (sanity)."""
    rng = np.random.default_rng(3)
    v, m = 24, 4
    vv = rng.normal(size=(v, m)).astype(np.float32)
    qw = rng.uniform(0.1, 1, size=v).astype(np.float32)
    qw /= qw.sum()
    # query == one database row, with the query coords = whole vocab
    x = qw[None, :].repeat(3, axis=0)
    fa, fb = model.lc_act_fused(vv, vv, qw, x, 2)
    assert_allclose(np.asarray(fa), 0.0, atol=1e-6)
    assert_allclose(np.asarray(fb), 0.0, atol=1e-6)


def test_dense_overlap_rwmd_collapses_act_does_not():
    """Paper Fig. 3 / Table 6 failure mode: full-overlap dense histograms.

    RWMD (k=1) sees cost 0 between *different* histograms; ACT-1 (k=2)
    produces a strictly positive distance.
    """
    rng = np.random.default_rng(4)
    v, m = 24, 4
    vv = rng.normal(size=(v, m)).astype(np.float32)
    qw = rng.uniform(0.1, 1, size=v).astype(np.float32)
    qw /= qw.sum()
    other = rng.uniform(0.1, 1, size=v).astype(np.float32)
    other /= other.sum()
    x = other[None, :]
    rwmd_a, rwmd_b = model.lc_act_fused(vv, vv, qw, x, 1)
    act_a, _ = model.lc_act_fused(vv, vv, qw, x, 2)
    assert float(np.asarray(rwmd_a)[0]) < 1e-6  # RWMD: blind
    assert float(np.asarray(rwmd_b)[0]) < 1e-6
    assert float(np.asarray(act_a)[0]) > 1e-4  # ACT-1: separates


def test_bound_chain_rwmd_le_act_le_ict_le_emd():
    """Theorem 2 on the LC pipeline vs LP oracle (small instance)."""
    vv, q, qw, x = make_instance(11, v=20, h=8, m=3, n=6)
    c_full = ref.pairwise_distance_ref(vv, q).astype(np.float64)
    t1, *_ = ref.lc_act_ref(vv, q, qw, x, 1)  # RWMD
    t2, *_ = ref.lc_act_ref(vv, q, qw, x, 2)  # ACT-1
    t4, *_ = ref.lc_act_ref(vv, q, qw, x, 4)  # ACT-3
    for u in range(x.shape[0]):
        supp = np.nonzero(x[u])[0]
        p = x[u][supp]
        c = c_full[supp]
        ict = ref.ict_pair_ref(p, qw, c)
        emd = ref.emd_pair_ref(p, qw, c)
        assert t1[u] <= t2[u] + 1e-6
        assert t2[u] <= t4[u] + 1e-6
        assert t4[u] <= ict + 1e-5
        assert ict <= emd + 1e-5
