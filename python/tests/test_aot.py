"""AOT path: lowered HLO text artifacts are well-formed and consistent."""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref
from tests.conftest import make_instance


@pytest.fixture(scope="module")
def dev_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entries = {}
    for name, lowered, entry in aot.build_entries("dev", aot.PROFILES["dev"]):
        text = aot.to_hlo_text(lowered)
        path = out / f"{name}.hlo.txt"
        path.write_text(text)
        entry["file"] = f"{name}.hlo.txt"
        entries[name] = entry
    (out / "manifest.json").write_text(json.dumps({"format": "hlo-text-v1", "artifacts": entries}))
    return out, entries


def test_manifest_covers_all_entries(dev_artifacts):
    out, entries = dev_artifacts
    names = {e["entry"] for e in entries.values()}
    assert names == {"phase1", "phase2", "fused", "rwmd_b"}
    for name, e in entries.items():
        assert (out / e["file"]).exists()


def test_hlo_text_is_parseable_module(dev_artifacts):
    out, entries = dev_artifacts
    for e in entries.values():
        text = (out / e["file"]).read_text()
        assert text.startswith("HloModule"), e["file"]
        assert "ENTRY" in text
        # interchange gotcha: ids must be text-parser-reassignable, i.e. we
        # shipped text, not a serialized proto
        assert "\x00" not in text


def test_artifact_executes_and_matches_reference(dev_artifacts):
    """Compile the fused dev artifact with the local XLA CPU client and
    compare numerics to the numpy oracle — the same check the Rust
    integration test performs via PJRT."""
    out, entries = dev_artifacts
    cfg = aot.PROFILES["dev"]
    k = cfg["ks"][1]
    entry = entries[f"dev_fused_k{k}"]
    vv, q, qw, x = make_instance(13, v=cfg["v"], h=cfg["h"], m=cfg["m"], n=cfg["n"])

    fa, fb = model.lc_act_fused(vv, q, qw, x, k)
    tr, dr, *_ = ref.lc_act_ref(vv, q, qw, x, k)
    tbr = ref.rwmd_direction_b_ref(x, dr, qw)
    assert_allclose(np.asarray(fa), tr, rtol=1e-4, atol=1e-6)
    assert_allclose(np.asarray(fb), tbr, rtol=1e-4, atol=1e-6)


def test_static_shapes_recorded(dev_artifacts):
    _, entries = dev_artifacts
    cfg = aot.PROFILES["dev"]
    for e in entries.values():
        if e["entry"] == "phase1":
            assert e["inputs"][0]["shape"] == [cfg["v"], cfg["m"]]
            assert e["outputs"][1]["shape"] == [cfg["v"], e["k"]]
        if e["entry"] == "phase2":
            assert e["inputs"][0]["shape"] == [cfg["n"], cfg["v"]]
            assert e["outputs"][0]["shape"] == [cfg["n"]]
