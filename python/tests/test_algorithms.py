"""Paper algorithms 1-3 and theorem properties on per-pair instances."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from tests.conftest import make_pair


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("overlap", [0.0, 0.5, 1.0])
def test_theorem2_chain(seed, overlap):
    """RWMD <= OMR <= ACT-k <= ACT-(k+1) <= ICT <= EMD (Theorem 2)."""
    p, q, c = make_pair(seed, h=10, m=3, overlap=overlap)
    rwmd = ref.rwmd_pair_ref(p, q, c)
    omr = ref.omr_pair_ref(p, q, c)
    acts = [ref.act_pair_ref(p, q, c, k) for k in (2, 3, 5, 8)]
    ict = ref.ict_pair_ref(p, q, c)
    emd = ref.emd_pair_ref(p, q, c)
    # tolerances absorb fp summation-order noise between the algorithms
    eps = 1e-7
    assert rwmd <= omr + eps
    prev = omr
    for a in acts:
        # OMR <= ACT-1 holds for effective costs; with overlap OMR uses the
        # overlap rule which ACT-1 (k=2) also captures.
        assert prev <= a + eps
        prev = a
    assert prev <= ict + eps
    assert ict <= emd + 1e-6


@pytest.mark.parametrize("seed", range(5))
def test_ict_equals_act_with_full_k(seed):
    """ACT with k = h_q and ICT coincide when capacities never exhaust."""
    p, q, c = make_pair(seed + 100, h=8, m=2)
    ict = ref.ict_pair_ref(p, q, c)
    act = ref.act_pair_ref(p, q, c, k=len(q))
    # ACT's top-k oracle stores f32 distances; compare at f32 resolution.
    assert np.isclose(ict, act, rtol=1e-6)


def test_ict_identity_is_zero():
    p, _, _ = make_pair(0, h=6, m=2)
    c = np.zeros((6, 6))
    assert ref.ict_pair_ref(p, p, c) == 0.0


def test_rwmd_blind_on_full_overlap():
    """Fig. 3: same coordinates, different weights -> RWMD = 0 (failure)."""
    p, q, c = make_pair(1, h=8, m=3, overlap=1.0)
    assert ref.rwmd_pair_ref(p, q, c) == 0.0
    assert ref.rwmd_pair_ref(q, p, c.T) == 0.0


def test_omr_effective_on_full_overlap():
    """Theorem 3: for effective costs, OMR(p,q)=0 iff p==q."""
    p, q, c = make_pair(2, h=8, m=3, overlap=1.0)
    assert not np.allclose(p, q)
    assert ref.omr_pair_ref(p, q, c) > 0.0
    # identical histograms -> 0
    assert ref.omr_pair_ref(p, p, c) == 0.0


def test_ict_optimal_vs_lp():
    """Theorem 1: ICT == LP optimum of the relaxed problem (1),(2),(4).

    Solved via scipy linprog with explicit capacity upper bounds.
    """
    from scipy.optimize import linprog

    p, q, c = make_pair(3, h=6, m=2, overlap=0.3)
    hp, hq = c.shape
    a_eq = np.zeros((hp, hp * hq))
    for i in range(hp):
        a_eq[i, i * hq : (i + 1) * hq] = 1.0
    bounds = [(0, q[j]) for _ in range(hp) for j in range(hq)]
    res = linprog(c.reshape(-1), A_eq=a_eq, b_eq=p, bounds=bounds, method="highs")
    assert res.status == 0
    ict = ref.ict_pair_ref(p, q, c)
    assert np.isclose(ict, res.fun, rtol=1e-8, atol=1e-10)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(2, 12), m=st.integers(1, 4), seed=st.integers(0, 2**31 - 1),
       overlap=st.sampled_from([0.0, 0.25, 0.75, 1.0]))
def test_hypothesis_chain(h, m, seed, overlap):
    p, q, c = make_pair(seed, h=h, m=m, overlap=overlap)
    rwmd = ref.rwmd_pair_ref(p, q, c)
    omr = ref.omr_pair_ref(p, q, c)
    act2 = ref.act_pair_ref(p, q, c, 2)
    ict = ref.ict_pair_ref(p, q, c)
    emd = ref.emd_pair_ref(p, q, c)
    assert rwmd <= omr + 1e-7
    assert omr <= act2 + 1e-7
    assert act2 <= ict + 1e-7
    assert ict <= emd + 1e-6
