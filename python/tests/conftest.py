"""Shared fixtures/helpers for the kernel test-suite."""

from __future__ import annotations

import numpy as np
import pytest


def make_instance(seed: int, v: int, h: int, m: int, n: int, sparsity: float = 0.7):
    """Random LC-ACT instance: vocab, query, normalized sparse DB tile."""
    rng = np.random.default_rng(seed)
    vv = rng.normal(size=(v, m)).astype(np.float32)
    q = rng.normal(size=(h, m)).astype(np.float32)
    qw = rng.uniform(size=h).astype(np.float32)
    qw /= qw.sum()
    x = rng.uniform(size=(n, v)).astype(np.float32)
    x[x < sparsity] = 0.0
    # keep at least one nonzero per row, then L1-normalize
    for u in range(n):
        if x[u].sum() == 0:
            x[u, rng.integers(0, v)] = 1.0
    x /= x.sum(axis=1, keepdims=True)
    return vv, q, qw, x


def make_pair(seed: int, h: int, m: int, overlap: float = 0.0):
    """Random normalized histogram pair + Euclidean cost matrix.

    ``overlap`` is the fraction of coordinates shared between p and q
    (exercises the dense/overlapping failure mode of RWMD, paper Section 4).
    """
    rng = np.random.default_rng(seed)
    cp = rng.normal(size=(h, m)).astype(np.float64)
    cq = rng.normal(size=(h, m)).astype(np.float64)
    n_shared = int(overlap * h)
    if n_shared:
        cq[:n_shared] = cp[:n_shared]
    p = rng.uniform(0.05, 1.0, size=h)
    q = rng.uniform(0.05, 1.0, size=h)
    p /= p.sum()
    q /= q.sum()
    c = np.sqrt(((cp[:, None, :] - cq[None, :, :]) ** 2).sum(-1))
    return p, q, c


@pytest.fixture(scope="session")
def small_instance():
    return make_instance(0, v=64, h=16, m=8, n=32)
