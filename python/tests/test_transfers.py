"""Phase-2/3 constrained-transfer kernel and direction-B RWMD kernel."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import constrained_transfers, rwmd_direction_b
from compile.kernels.ref import (
    constrained_transfers_ref,
    rwmd_direction_b_ref,
)
from tests.conftest import make_instance
from compile.kernels.ref import lc_act_ref


def _zw(seed, v, k, h):
    """Random plausible (Z, W): ascending distances, weights in [0, 1]."""
    rng = np.random.default_rng(seed)
    z = np.sort(rng.uniform(0.1, 2.0, size=(v, k)), axis=1).astype(np.float32)
    w = rng.uniform(0.0, 2.0 / h, size=(v, k)).astype(np.float32)
    return z, w


@pytest.mark.parametrize("n,v,k", [(8, 16, 1), (32, 64, 2), (16, 48, 8), (64, 32, 16)])
def test_matches_reference(n, v, k):
    rng = np.random.default_rng(n + v + k)
    x = rng.uniform(size=(n, v)).astype(np.float32)
    x[x < 0.6] = 0
    x /= np.maximum(x.sum(1, keepdims=True), 1e-9)
    z, w = _zw(n * v + k, v, k, 16)
    out = np.asarray(constrained_transfers(x, z, w))
    assert_allclose(out, constrained_transfers_ref(x, z, w), rtol=1e-4, atol=1e-6)


def test_k1_is_rwmd_dot_product():
    """With k=1 Phase 2 degenerates to LC-RWMD: t = X . z1."""
    rng = np.random.default_rng(5)
    n, v = 16, 32
    x = rng.uniform(size=(n, v)).astype(np.float32)
    z = rng.uniform(0.1, 1.0, size=(v, 1)).astype(np.float32)
    w = rng.uniform(size=(v, 1)).astype(np.float32)
    out = np.asarray(constrained_transfers(x, z, w))
    assert_allclose(out, x @ z[:, 0], rtol=1e-5)


def test_huge_capacity_reduces_to_first_distance():
    """If w >= row mass, everything moves at the smallest distance."""
    rng = np.random.default_rng(6)
    n, v, k = 8, 24, 4
    x = rng.uniform(size=(n, v)).astype(np.float32)
    z = np.sort(rng.uniform(0.1, 2.0, size=(v, k)), axis=1).astype(np.float32)
    w = np.full((v, k), 1e9, np.float32)
    out = np.asarray(constrained_transfers(x, z, w))
    assert_allclose(out, x @ z[:, 0], rtol=1e-5)


def test_zero_capacity_charges_kth_distance():
    """If all capacities are zero, all mass ships at the k-th distance."""
    rng = np.random.default_rng(7)
    n, v, k = 8, 24, 4
    x = rng.uniform(size=(n, v)).astype(np.float32)
    z = np.sort(rng.uniform(0.1, 2.0, size=(v, k)), axis=1).astype(np.float32)
    w = np.zeros((v, k), np.float32)
    out = np.asarray(constrained_transfers(x, z, w))
    assert_allclose(out, x @ z[:, k - 1], rtol=1e-5)


def test_padding_rows_cost_zero():
    z, w = _zw(8, 16, 4, 8)
    x = np.zeros((4, 16), np.float32)
    out = np.asarray(constrained_transfers(x, z, w))
    assert_allclose(out, np.zeros(4), atol=1e-7)


def test_monotone_in_k_prefix():
    """Adding an iteration can only tighten (raise) the bound when Z is
    ascending: ACT-(k-1) <= ACT-k <= ... computed via prefix sub-matrices."""
    vv, q, qw, x = make_instance(21, v=48, h=12, m=4, n=16)
    ts = []
    for k in (1, 2, 4, 8):
        t, *_ = lc_act_ref(vv, q, qw, x, k)
        ts.append(t.astype(np.float64))
    for a, b in zip(ts, ts[1:]):
        assert (b - a >= -1e-5).all()


@pytest.mark.parametrize("n,v,h", [(8, 16, 8), (32, 64, 16), (16, 100, 7)])
def test_rwmd_b_matches_reference(n, v, h):
    rng = np.random.default_rng(n * v + h)
    x = rng.uniform(size=(n, v)).astype(np.float32)
    x[x < 0.7] = 0
    d = rng.uniform(0.01, 3.0, size=(v, h)).astype(np.float32)
    qw = rng.uniform(size=h).astype(np.float32)
    qw /= qw.sum()
    out = np.asarray(rwmd_direction_b(x, d, qw))
    assert_allclose(out, rwmd_direction_b_ref(x, d, qw), rtol=1e-4, atol=1e-6)


def test_rwmd_b_empty_row_is_zero():
    rng = np.random.default_rng(3)
    x = np.zeros((4, 16), np.float32)
    x[0, 2] = 1.0
    d = rng.uniform(0.5, 1.0, size=(16, 8)).astype(np.float32)
    qw = np.full(8, 1 / 8, np.float32)
    out = np.asarray(rwmd_direction_b(x, d, qw))
    assert out[0] > 0
    assert_allclose(out[1:], 0.0, atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 40),
    v=st.integers(1, 64),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_transfers_sweep(n, v, k, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(size=(n, v)).astype(np.float32)
    x[x < rng.uniform(0, 0.9)] = 0
    z = np.sort(rng.uniform(0, 3, size=(v, k)), axis=1).astype(np.float32)
    w = rng.uniform(0, 0.5, size=(v, k)).astype(np.float32)
    out = np.asarray(constrained_transfers(x, z, w))
    assert_allclose(out, constrained_transfers_ref(x, z, w), rtol=1e-3, atol=1e-5)
