"""Pallas pairwise-distance kernel vs pure-numpy oracle."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import pairwise_distance
from compile.kernels.ref import pairwise_distance_ref


@pytest.mark.parametrize(
    "v,h,m",
    [(8, 4, 2), (64, 16, 8), (128, 32, 3), (96, 7, 300), (784, 12, 2), (50, 50, 64)],
)
def test_matches_reference(v, h, m):
    rng = np.random.default_rng(v * 1000 + h * 10 + m)
    vv = rng.normal(size=(v, m)).astype(np.float32)
    q = rng.normal(size=(h, m)).astype(np.float32)
    out = np.asarray(pairwise_distance(vv, q))
    assert_allclose(out, pairwise_distance_ref(vv, q), rtol=1e-4, atol=1e-5)


def test_identical_rows_give_zero():
    rng = np.random.default_rng(1)
    vv = rng.normal(size=(16, 4)).astype(np.float32)
    out = np.asarray(pairwise_distance(vv, vv))
    assert_allclose(np.diag(out), np.zeros(16), atol=1e-5)


def test_nonnegative_even_with_cancellation():
    # Large-magnitude nearly-identical coordinates stress the
    # ||v||^2 - 2vq + ||q||^2 cancellation path the kernel clamps.
    base = np.full((32, 8), 1e3, np.float32)
    jit = base + np.random.default_rng(2).normal(scale=1e-3, size=(32, 8)).astype(np.float32)
    out = np.asarray(pairwise_distance(base, jit))
    assert (out >= 0).all()


def test_explicit_block_size():
    rng = np.random.default_rng(3)
    vv = rng.normal(size=(60, 5)).astype(np.float32)
    q = rng.normal(size=(9, 5)).astype(np.float32)
    a = np.asarray(pairwise_distance(vv, q, block_v=20))
    b = np.asarray(pairwise_distance(vv, q, block_v=60))
    assert_allclose(a, b, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    v=st.integers(1, 96),
    h=st.integers(1, 48),
    m=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shape_sweep(v, h, m, seed):
    rng = np.random.default_rng(seed)
    vv = (rng.normal(size=(v, m)) * rng.uniform(0.1, 10)).astype(np.float32)
    q = (rng.normal(size=(h, m)) * rng.uniform(0.1, 10)).astype(np.float32)
    out = np.asarray(pairwise_distance(vv, q))
    ref = pairwise_distance_ref(vv, q)
    assert out.shape == (v, h)
    # The kernel snaps d^2 below 1e-6 * (|v|^2 + |q|^2) to exactly zero
    # (overlap detection, see distance.py); accept 0 inside that band.
    scale = (vv * vv).sum(1)[:, None] + (q * q).sum(1)[None, :]
    snap_band = ref.astype(np.float64) ** 2 <= 4e-6 * scale
    ok = np.isclose(out, ref, rtol=1e-3, atol=1e-4) | (snap_band & (out == 0.0))
    assert ok.all()
