//! Reproduce paper Fig. 8(a): runtime-vs-accuracy on the (synthetic)
//! 20-Newsgroups corpus — BoW, WCD, RWMD, OMR, ACT-1/3/7, and the
//! prune-accelerated exact WMD on a query subset.
//!
//! ```bash
//! cargo run --release --example text_search -- [--n 2000] [--wmd-queries 20]
//! ```

use std::time::Instant;

use emdpar::data::{generate_text, TextConfig};
use emdpar::eval::{precision_at, render_markdown, sweep_all_pairs};
use emdpar::exact::wmd_topl_pruned;
use emdpar::prelude::{EmdResult, EngineParams, Method, Metric};
use emdpar::util::cli::CommandSpec;
use emdpar::util::stats::fmt_duration;

fn main() -> EmdResult<()> {
    let spec = CommandSpec::new("text_search", "Fig. 8(a): 20News runtime vs accuracy")
        .opt("n", "2000", "corpus size")
        .opt("vocab", "8000", "vocabulary size")
        .opt("dim", "64", "embedding dimension")
        .opt("ls", "1,16,128", "top-ℓ values")
        .opt("wmd-queries", "20", "queries for the exact-WMD comparator (0 = skip)")
        .opt("threads", "0", "worker threads (0 = auto)");
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("cargo run --example"));
        return Ok(());
    }
    let p = spec.parse(&args)?;
    let n = p.usize("n")?;
    let threads = match p.usize("threads")? {
        0 => emdpar::util::threadpool::default_threads(),
        t => t,
    };

    // harder-than-default corpus: short, noisy documents over a wide
    // vocabulary, so same-class documents share few literal words and the
    // BoW/RWMD/ACT separation of paper Fig. 8(a) is visible
    let ds = std::sync::Arc::new(generate_text(&TextConfig {
        n,
        vocab: p.usize("vocab")?,
        dim: p.usize("dim")?,
        doc_len: 30,
        spread: 0.5,
        topic_frac: 0.45,
        general_frac: 0.35,
        ..Default::default()
    }));
    let stats = ds.stats();
    println!(
        "# {} — n={} avg_h={:.1} vocab={} m={} (paper: n=18828 avg_h=78.8 v=69682 m=300)\n",
        ds.name, stats.n, stats.avg_h, stats.used_vocab, stats.dim
    );

    let ls = p.usize_list("ls")?;
    let ls: Vec<usize> = ls.into_iter().filter(|&l| l < n).collect();
    let methods = [
        Method::Bow,
        Method::Wcd,
        Method::Rwmd,
        Method::Omr,
        Method::Act { k: 2 },
        Method::Act { k: 4 },
        Method::Act { k: 8 },
    ];
    let rows = sweep_all_pairs(
        &ds,
        &methods,
        &ls,
        EngineParams { threads, ..Default::default() },
    )?;
    println!("{}", render_markdown("Fig. 8(a) — runtime vs accuracy (all-pairs)", &rows));

    // exact WMD on a query subset (the paper's 4-orders-of-magnitude foil)
    let wmd_q = p.usize("wmd-queries")?.min(n);
    if wmd_q > 0 {
        let db: Vec<_> = (0..ds.len()).map(|u| ds.histogram(u)).collect();
        let lmax = ls.iter().copied().max().unwrap_or(16);
        let t0 = Instant::now();
        let mut evals_total = 0usize;
        let mut dist = vec![0.0f32; wmd_q * n];
        for uq in 0..wmd_q {
            let (top, evals) = wmd_topl_pruned(&ds.embeddings, &db[uq], &db, Metric::L2, lmax + 1);
            evals_total += evals;
            // fill a distance row: unreturned candidates get +inf
            let row = &mut dist[uq * n..(uq + 1) * n];
            row.fill(f32::INFINITY);
            for (d, u) in top {
                row[u] = d as f32;
            }
        }
        let elapsed = t0.elapsed();
        let prec = precision_at(&dist, &ds.labels[..wmd_q], &ds.labels, lmax.min(16), true);
        let per_pair = elapsed.as_secs_f64() / (wmd_q * n) as f64;
        println!(
            "### WMD comparator (exact EMD + RWMD prune)\n\
             {} queries x {} docs: {} total, {:.3e} pairs/s ({} exact EMD evals)\n\
             precision@{} = {prec:.4}\n",
            wmd_q,
            n,
            fmt_duration(elapsed),
            1.0 / per_pair,
            evals_total,
            lmax.min(16),
        );
        // headline speedup: ACT-1 throughput / WMD throughput
        if let Some(act1) = rows.iter().find(|r| r.method == "ACT-1") {
            println!(
                "speedup ACT-1 vs WMD: {:.0}x  (paper: ~4 orders of magnitude on GPU)",
                act1.throughput() * per_pair
            );
        }
    }
    Ok(())
}
