//! End-to-end serving driver: boot the coordinator + TCP server on a real
//! (synthetic) image database, fire concurrent batched client load at it,
//! and report latency/throughput — the "serving" proof that all three
//! layers compose behind the request path.
//!
//! ```bash
//! cargo run --release --example serve_demo -- [--n 2000] [--clients 4] [--requests 50]
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

use emdpar::emd_ensure;
use emdpar::prelude::{DatasetSpec, EmdError, EmdResult, EngineBuilder, IndexParams, Server};
use emdpar::util::cli::CommandSpec;
use emdpar::util::json::Json;
use emdpar::util::stats::Summary;

fn main() -> EmdResult<()> {
    let spec = CommandSpec::new("serve_demo", "end-to-end serving load test")
        .opt("n", "2000", "database size")
        .opt("clients", "4", "concurrent client connections")
        .opt("requests", "50", "requests per client")
        .opt("method", "act-1", "distance method")
        .opt("l", "10", "results per query")
        .opt("nlist", "32", "IVF index lists (0 disables the index)")
        .opt("nprobe", "4", "IVF lists probed per query");
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("cargo run --example"));
        return Ok(());
    }
    let p = spec.parse(&args)?;
    let n = p.usize("n")?;
    let clients = p.usize("clients")?;
    let requests = p.usize("requests")?;
    let method = p.str("method").to_string();
    let l = p.usize("l")?;
    let nlist = p.usize("nlist")?;
    let nprobe = p.usize("nprobe")?;

    let mut builder = EngineBuilder::new()
        .dataset_spec(DatasetSpec::SynthMnist { n, background: 0.0, seed: 42 })
        .max_batch(8)
        .linger_ms(1);
    if nlist > 0 {
        // the IVF pruning index: queries score only the probed lists'
        // candidates instead of all n documents
        builder = builder.index(IndexParams {
            nlist,
            nprobe: nprobe.max(1),
            ..Default::default()
        });
    }
    let engine = builder.build_search()?;
    println!(
        "database: {} docs ({}), serving '{}' top-{l}",
        engine.dataset().len(),
        engine.dataset().name,
        method
    );
    match engine.index() {
        Some(ix) => println!(
            "index:      {} lists, probing {} per query (exhaustive when nprobe >= nlist)",
            ix.nlist(),
            nprobe
        ),
        None => println!("index:      disabled (exhaustive search)"),
    }
    let metrics = engine.metrics();
    let server = Server::bind(engine, "127.0.0.1:0")?;
    let addr = server.local_addr()?;

    let accept = std::thread::spawn({
        move || server.serve_n(clients)
    });

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let method = method.clone();
        handles.push(std::thread::spawn(move || -> EmdResult<Vec<f64>> {
            let stream = TcpStream::connect(addr)?;
            let mut reader = BufReader::new(stream.try_clone()?);
            let mut w = stream;
            let mut latencies = Vec::with_capacity(requests);
            for r in 0..requests {
                let id = (c * 7919 + r * 13) % n;
                let req = format!(
                    "{{\"op\": \"search_id\", \"id\": {id}, \"l\": {l}, \"method\": \"{method}\"}}\n"
                );
                let t = Instant::now();
                w.write_all(req.as_bytes())?;
                w.flush()?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                latencies.push(t.elapsed().as_secs_f64());
                let json = Json::parse(line.trim()).map_err(|e| EmdError::json(e.to_string()))?;
                emd_ensure!(
                    json.get("ok") == Some(&Json::Bool(true)),
                    "server error: {line}"
                );
            }
            Ok(latencies)
        }));
    }

    let mut all: Vec<f64> = Vec::new();
    for h in handles {
        all.extend(h.join().expect("client thread")?);
    }
    accept.join().expect("accept thread")?;
    let wall = t0.elapsed().as_secs_f64();

    let s = Summary::from(&all);
    let total = clients * requests;
    println!("\n=== load test ===");
    println!("requests:   {total} over {clients} connections");
    println!("throughput: {:.1} queries/s (wall {:.2}s)", total as f64 / wall, wall);
    println!(
        "latency:    p50 {:.2} ms   p95 {:.2} ms   max {:.2} ms",
        s.p50 * 1e3,
        s.p95 * 1e3,
        s.max * 1e3
    );
    println!(
        "server:     {} batches for {} queries (mean batch {:.2})",
        metrics.batches.load(std::sync::atomic::Ordering::Relaxed),
        metrics.queries.load(std::sync::atomic::Ordering::Relaxed),
        metrics.queries.load(std::sync::atomic::Ordering::Relaxed) as f64
            / metrics.batches.load(std::sync::atomic::Ordering::Relaxed).max(1) as f64
    );
    let index_queries = metrics.index_queries.load(std::sync::atomic::Ordering::Relaxed);
    if index_queries > 0 {
        println!(
            "pruning:    {index_queries} queries through the index, {} lists probed, \
             {} candidates scored ({:.1}% of the database pruned)",
            metrics.lists_probed.load(std::sync::atomic::Ordering::Relaxed),
            metrics.candidates_scored.load(std::sync::atomic::Ordering::Relaxed),
            100.0 * metrics.pruned_fraction()
        );
    }
    println!("metrics:    {}", metrics.to_json().to_string_compact());
    Ok(())
}
