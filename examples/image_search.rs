//! Reproduce paper Tables 5 & 6: precision@top-ℓ on the (synthetic) MNIST
//! database, without background (`Table 5`) and with background pixels
//! (`Table 6`, the RWMD failure mode).
//!
//! ```bash
//! cargo run --release --example image_search -- [--background] [--n 2000]
//! ```

use emdpar::data::{generate_mnist, MnistConfig};
use emdpar::eval::{render_markdown, sweep_all_pairs};
use emdpar::prelude::{EmdResult, EngineParams, Method};
use emdpar::util::cli::CommandSpec;

fn main() -> EmdResult<()> {
    let spec = CommandSpec::new("image_search", "Tables 5/6: MNIST precision@top-ℓ")
        .opt("n", "2000", "database size")
        .opt("ls", "1,16,128", "top-ℓ values")
        .opt("threads", "0", "worker threads (0 = auto)")
        .flag("background", "include background mass (Table 6)");
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help") {
        println!("{}", spec.usage("cargo run --example"));
        return Ok(());
    }
    let p = spec.parse(&args)?;
    let n = p.usize("n")?;
    let background = if p.flag("background") { 0.4 } else { 0.0 };
    let threads = match p.usize("threads")? {
        0 => emdpar::util::threadpool::default_threads(),
        t => t,
    };

    let ds = std::sync::Arc::new(generate_mnist(&MnistConfig { n, background, ..Default::default() }));
    let stats = ds.stats();
    println!(
        "# {} — n={} avg_h={:.1} vocab={} (paper: n=60000 avg_h=149.9 v=717)\n",
        ds.name, stats.n, stats.avg_h, stats.used_vocab
    );

    let (methods, title): (Vec<Method>, &str) = if background > 0.0 {
        (
            vec![Method::Bow, Method::Rwmd, Method::Omr, Method::Act { k: 8 }, Method::Act { k: 16 }],
            "Table 6 — precision@top-ℓ, MNIST WITH background",
        )
    } else {
        (
            vec![Method::Bow, Method::Rwmd, Method::Act { k: 2 }, Method::Act { k: 4 }, Method::Act { k: 8 }],
            "Table 5 — precision@top-ℓ, MNIST without background",
        )
    };
    let ls = p.usize_list("ls")?;
    let ls: Vec<usize> = ls.into_iter().filter(|&l| l < n).collect();

    let rows = sweep_all_pairs(
        &ds,
        &methods,
        &ls,
        EngineParams { threads, ..Default::default() },
    )?;
    println!("{}", render_markdown(title, &rows));

    if background > 0.0 {
        let rwmd = rows.iter().find(|r| r.method == "RWMD").unwrap();
        println!(
            "note: RWMD precision ≈ {:.2} ≈ 1/10 — the paper's Table-6 collapse\n\
             (all coordinates overlap, every RWMD distance is 0).",
            rwmd.precision[0].1
        );
    }
    Ok(())
}
