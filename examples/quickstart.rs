//! Quickstart: generate a small image dataset, search it with every method
//! through the coordinator, and (when `make artifacts` has run) execute the
//! same query through the AOT-compiled JAX/Pallas pipeline via PJRT.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::path::Path;

use emdpar::config::{Config, DatasetSpec};
use emdpar::coordinator::SearchEngine;
use emdpar::data::{generate_text, TextConfig};
use emdpar::lc::Method;
use emdpar::runtime::{ArtifactEngine, Executor};

fn main() -> anyhow::Result<()> {
    // 1. a small synthetic digit database behind the coordinator
    let config = Config {
        dataset: DatasetSpec::SynthMnist { n: 500, background: 0.0, seed: 42 },
        topl: 5,
        ..Default::default()
    };
    let engine = SearchEngine::from_config(config)?;
    let stats = engine.dataset().stats();
    println!(
        "dataset: {} (n={}, avg_h={:.1}, vocab={}, m={})\n",
        engine.dataset().name, stats.n, stats.avg_h, stats.vocab_size, stats.dim
    );

    // 2. query image #0 under each distance measure
    let query = engine.dataset().histogram(0);
    let label = engine.dataset().labels[0];
    println!("query: image 0, digit class {label}");
    for method in [
        Method::Bow,
        Method::Wcd,
        Method::Rwmd,
        Method::Omr,
        Method::Act { k: 2 },
        Method::Act { k: 8 },
    ] {
        let res = engine.search(&query, method, 5)?;
        let labels: Vec<u16> = res.labels.clone();
        println!(
            "  {:<6} top-5 labels {:?}  best distance {:.4}",
            method.name(),
            labels,
            res.hits[0].0
        );
    }
    let m = engine.metrics();
    println!(
        "\ncoordinator metrics: {} queries, mean latency {:.1} us",
        m.queries.load(std::sync::atomic::Ordering::Relaxed),
        m.mean_latency_us()
    );

    // 3. the same pipeline through the PJRT artifact path (three layers:
    //    Pallas kernel -> JAX pipeline -> Rust runtime)
    let artifact_dir = Path::new("artifacts");
    match Executor::new(artifact_dir) {
        Ok(exec) => {
            println!("\nPJRT backend: platform '{}'", exec.platform());
            // dev-profile-sized text dataset for the artifact demo
            let spec = exec.manifest().artifacts.values().find(|a| a.profile == "dev").unwrap();
            let ds = generate_text(&TextConfig {
                n: 128,
                classes: 4,
                vocab: spec.v,
                dim: spec.m,
                doc_len: spec.h / 2,
                seed: 3,
                ..Default::default()
            });
            let art = ArtifactEngine::new(&exec, &ds, "dev")?;
            let q = ds.histogram(0);
            let d = art.distances(&q, 2, true)?;
            let mut best: Vec<usize> = (0..d.len()).collect();
            best.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
            println!(
                "artifact ACT-1 top-5 for text doc 0 (label {}): {:?}",
                ds.labels[0],
                best[..5].iter().map(|&u| (u, ds.labels[u])).collect::<Vec<_>>()
            );
        }
        Err(e) => println!("\n(skipping PJRT demo: {e}; run `make artifacts`)"),
    }
    Ok(())
}
