//! Quickstart: build the engine stack with `EngineBuilder`, search a small
//! image database under every method through the coordinator, run a
//! cascaded exact-EMD search, and (when `make artifacts` has run and the
//! crate is built with `--features pjrt`) execute the same query through
//! the AOT-compiled JAX/Pallas pipeline via PJRT.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::path::Path;

use emdpar::data::{generate_text, TextConfig};
use emdpar::prelude::{
    CascadeSpec, DatasetSpec, Distance, EmdResult, EngineBuilder, Method, MethodRegistry,
    SearchRequest,
};
use emdpar::runtime::{ArtifactEngine, Executor};

fn main() -> EmdResult<()> {
    // 1. a small synthetic digit database behind the coordinator,
    //    assembled by the builder (dataset -> params -> build)
    let engine = EngineBuilder::new()
        .dataset_spec(DatasetSpec::SynthMnist { n: 500, background: 0.0, seed: 42 })
        .topl(5)
        .build_search()?;
    let stats = engine.dataset().stats();
    println!(
        "dataset: {} (n={}, avg_h={:.1}, vocab={}, m={})\n",
        engine.dataset().name, stats.n, stats.avg_h, stats.vocab_size, stats.dim
    );

    // 2. query image #0 under each distance measure — one canonical enum,
    //    one composable request type, one execute entry point
    let query = engine.dataset().histogram(0);
    let label = engine.dataset().labels[0];
    println!("query: image 0, digit class {label}");
    for method in [
        Method::Bow,
        Method::Wcd,
        Method::Rwmd,
        Method::Omr,
        Method::Act { k: 2 },
        Method::Act { k: 8 },
    ] {
        let request = SearchRequest::query(query.clone()).method(method).topl(5);
        let response = engine.execute(&request)?;
        let res = &response.results[0];
        println!(
            "  {:<6} top-5 labels {:?}  best distance {:.4}",
            method.name(),
            res.labels,
            res.hits[0].0
        );
    }
    let m = engine.metrics();
    println!(
        "\ncoordinator metrics: {} queries, mean latency {:.1} us",
        m.queries.load(std::sync::atomic::Ordering::Relaxed),
        m.mean_latency_us()
    );

    // 3. exact EMD through the cascade stage of the planner: RWMD prefilter
    //    over the database, min-cost-flow only on the survivors — the same
    //    request shape composes with IVF pruning and sharded corpora
    let request = SearchRequest::query(query.clone())
        .topl(5)
        .cascade(CascadeSpec::new(Method::Exact).overfetch(8).certified(true));
    let response = engine.execute(&request)?;
    println!("\nplan: {}", response.plan.describe());
    println!(
        "cascade (RWMD -> exact EMD): reranked {} of {} docs, certified: {}",
        response.stats.reranked,
        engine.num_docs(),
        response.stats.certified[0]
    );
    let res = &response.results[0];
    for (rank, (&(d, hit), &lab)) in res.hits.iter().zip(&res.labels).enumerate() {
        println!("  #{:<3} id={hit:<6} label={lab:<4} emd={d:.4}", rank + 1);
    }

    // 4. per-pair trait objects from the registry: every method, including
    //    the quadratic comparators, behind one interface
    let registry = MethodRegistry::new(engine.config().metric);
    let (a, b) = (engine.dataset().histogram(0), engine.dataset().histogram(1));
    println!("\nper-pair distances, image 0 vs image 1:");
    for method in [Method::BowAdjusted, Method::Rwmd, Method::Act { k: 4 }, Method::Ict, Method::Sinkhorn, Method::Exact] {
        let d = registry.distance(method);
        println!(
            "  {:<8} {:.5}",
            d.name(),
            d.distance(&engine.dataset().embeddings, &a, &b)?
        );
    }

    // 5. the same pipeline through the PJRT artifact path (three layers:
    //    Pallas kernel -> JAX pipeline -> Rust runtime)
    let artifact_dir = Path::new("artifacts");
    match Executor::new(artifact_dir) {
        Ok(exec) => {
            println!("\nPJRT backend: platform '{}'", exec.platform());
            // dev-profile-sized text dataset for the artifact demo
            let spec = exec.manifest().artifacts.values().find(|a| a.profile == "dev").unwrap();
            let ds = generate_text(&TextConfig {
                n: 128,
                classes: 4,
                vocab: spec.v,
                dim: spec.m,
                doc_len: spec.h / 2,
                seed: 3,
                ..Default::default()
            });
            let art = ArtifactEngine::new(&exec, &ds, "dev")?;
            let q = ds.histogram(0);
            let d = art.distances(&q, 2, true)?;
            let mut best: Vec<usize> = (0..d.len()).collect();
            best.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
            println!(
                "artifact ACT-1 top-5 for text doc 0 (label {}): {:?}",
                ds.labels[0],
                best[..5].iter().map(|&u| (u, ds.labels[u])).collect::<Vec<_>>()
            );
        }
        Err(e) => println!("\n(skipping PJRT demo: {e}; run `make artifacts` + `--features pjrt`)"),
    }
    Ok(())
}
